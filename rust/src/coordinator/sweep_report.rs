//! The sweep *report* data model: per-cell results aggregated into
//! group summaries (including per-epoch mean/p95 trajectories for
//! dynamic-schedule groups), the bit-exact result fingerprint, and the
//! JSON artifact surface — serialization, index-verified loading, and
//! hash-verified shard merge via the engine's artifact layer
//! ([`crate::coordinator::exec::artifact`]).
//!
//! The grid *definition* and execution entry points live in
//! [`super::sweep`]; this module owns everything about a sweep's
//! *outputs*: [`SweepReport`], [`GroupSummary`], [`CellFingerprint`],
//! and the serde impls of [`CellResult`].

use anyhow::{bail, Context, Result};

use crate::model::strategy::Strategy;
use crate::util::json::Json;
use crate::util::stats::summarize;
use crate::util::table::{fnum, Table};

use super::dynamics::PatternSchedule;
use super::exec::artifact::{f64_bits_hex, parse_f64_bits_hex, u64_hex, Artifact, ArtifactItem};
use super::exec::grid::GridCell;
use super::sweep::{CellCache, CellDivergence, CellResult, CellSim, SweepCell};
use super::{Algorithm, CellBackend};

/// Aggregate over the seeds of one
/// `(scenario, algorithm, backend, schedule)` group.
#[derive(Clone, Debug)]
pub struct GroupSummary {
    pub scenario: String,
    pub algorithm: String,
    pub backend: String,
    pub schedule: String,
    pub cells: usize,
    pub mean_cost: f64,
    pub p95_cost: f64,
    pub mean_iters_to_1pct: f64,
    pub mean_wall_seconds: f64,
    /// Per-epoch mean cost trajectory across the group's cells (empty for
    /// static-schedule groups, whose cells record no epochs).
    pub epoch_mean_cost: Vec<f64>,
    /// Per-epoch p95 cost trajectory across the group's cells.
    pub epoch_p95_cost: Vec<f64>,
    /// Mean across the group's cells of the simulated sojourn digests
    /// (p50, p99, p999, mean); `None` for groups without request-level
    /// simulation ([`super::sweep::SweepSpec::sim`] unset).
    pub sim_mean: Option<CellSim>,
    /// Mean of the cells' closed-loop `mean_rel_err`; `None` for groups
    /// without `--sim-validate`.
    pub sim_mean_rel_err: Option<f64>,
    /// Number of the group's cells whose validation alarm fired.
    pub sim_alarms: usize,
    /// Strategy-store aggregate across the group's cells with a cache
    /// record: `(verified hits, iterations those hits avoided)`. `None`
    /// when no cell in the group consulted a store (cache off, or an
    /// algorithm outside [`Algorithm::supports_warm_start`]).
    pub cache_hits: Option<(usize, usize)>,
}

/// A completed sweep: per-cell results in grid order plus aggregation.
#[derive(Clone, Debug)]
pub struct SweepReport {
    pub cells: Vec<CellResult>,
    /// Worker threads used (total budget for sharded runs). Metadata only
    /// — like wall times, excluded from [`SweepReport::fingerprint`].
    pub workers: usize,
    /// Identity of the generating spec ([`super::sweep::spec_grid_hash`]);
    /// `0` when unknown (hand-built reports). [`SweepReport::merge`]
    /// refuses to combine shard reports whose nonzero hashes differ.
    pub grid_hash: u64,
}

/// One cell's identity inside [`SweepReport::fingerprint`]: scenario,
/// seed, algorithm, backend, schedule label, cost bits, per-epoch cost
/// bits (empty for static cells), iterations, iters-to-1%, and the
/// simulated sojourn digest bits (`[p50, p99, p999, mean]`; empty when
/// the cell ran without request-level simulation; extended with
/// `[mean_rel_err, max_server_rel_err, alarm]` bits when the cell was
/// closed-loop validated).
pub type CellFingerprint = (
    String,
    u64,
    String,
    String,
    String,
    u64,
    Vec<u64>,
    usize,
    usize,
    Vec<u64>,
);

impl CellResult {
    /// Machine-readable cell record. `final_cost` is duplicated as exact
    /// bits (`final_cost_bits`, hex): JSON numbers cannot carry `±∞`
    /// (serialized as `null`) and decimal round-trips are not part of the
    /// determinism contract — the bits field is authoritative for
    /// [`CellResult::from_json`].
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("index", Json::Num(self.index as f64))
            .set("scenario", Json::Str(self.cell.scenario.clone()))
            .set("seed", Json::Num(self.cell.seed as f64))
            .set(
                "algorithm",
                Json::Str(self.cell.algorithm.name().to_string()),
            )
            .set("backend", Json::Str(self.cell.backend.name().to_string()))
            .set("schedule", Json::Str(self.cell.schedule.label()))
            .set("final_cost", Json::Num(self.final_cost))
            .set("final_cost_bits", Json::Str(f64_bits_hex(self.final_cost)))
            .set("iterations", Json::Num(self.iterations as f64))
            .set("iters_to_1pct", Json::Num(self.iters_to_1pct as f64))
            .set("wall_seconds", Json::Num(self.wall_seconds));
        if !self.epoch_costs.is_empty() {
            o.set(
                "epoch_cost_bits",
                Json::Arr(
                    self.epoch_costs
                        .iter()
                        .map(|c| Json::Str(f64_bits_hex(*c)))
                        .collect(),
                ),
            );
        }
        if let Some(sim) = &self.sim {
            // readable decimals plus authoritative bits, like final_cost
            let mut s = Json::obj();
            s.set("p50", Json::Num(sim.p50))
                .set("p50_bits", Json::Str(f64_bits_hex(sim.p50)))
                .set("p99", Json::Num(sim.p99))
                .set("p99_bits", Json::Str(f64_bits_hex(sim.p99)))
                .set("p999", Json::Num(sim.p999))
                .set("p999_bits", Json::Str(f64_bits_hex(sim.p999)))
                .set("mean", Json::Num(sim.mean))
                .set("mean_bits", Json::Str(f64_bits_hex(sim.mean)));
            if let Some(d) = &sim.divergence {
                s.set("mean_rel_err", Json::Num(d.mean_rel_err))
                    .set("mean_rel_err_bits", Json::Str(f64_bits_hex(d.mean_rel_err)))
                    .set("max_server_rel_err", Json::Num(d.max_server_rel_err))
                    .set(
                        "max_server_rel_err_bits",
                        Json::Str(f64_bits_hex(d.max_server_rel_err)),
                    )
                    .set("alarm", Json::Bool(d.alarm));
            }
            // admission-control columns: present iff the sweep ran with
            // --sim-queue-cap; uncapped records keep their historical bytes
            if let (Some(dropped), Some(mb)) = (sim.queue_dropped, sim.max_blocking) {
                s.set("queue_dropped", crate::sim::telemetry::num_u64(dropped))
                    .set("max_blocking", Json::Num(mb))
                    .set("max_blocking_bits", Json::Str(f64_bits_hex(mb)));
            }
            o.set("sim", s);
        }
        if let Some(cache) = &self.cache {
            let mut c = Json::obj();
            c.set("hit", Json::Bool(cache.hit))
                .set("iters_saved", Json::Num(cache.iters_saved as f64));
            o.set("cache", c);
        }
        if let Some(phi) = &self.phi {
            // bits-exact and digest-sealed (Strategy::to_json): the shard
            // protocol and report artifacts carry the converged strategy
            // itself when the sweep ran store-enabled
            o.set("strategy", phi.to_json());
        }
        o
    }

    /// Parse a cell record produced by [`CellResult::to_json`] (or a
    /// protocol line carrying the same fields).
    pub fn from_json(doc: &Json) -> Result<CellResult> {
        let scenario = doc
            .get("scenario")
            .as_str()
            .context("cell record missing scenario")?
            .to_string();
        let seed = doc.get("seed").as_num().context("cell record missing seed")? as u64;
        let algorithm = {
            let a = doc
                .get("algorithm")
                .as_str()
                .context("cell record missing algorithm")?;
            Algorithm::parse(a).with_context(|| format!("unknown algorithm '{a}'"))?
        };
        let backend = {
            let b = doc
                .get("backend")
                .as_str()
                .context("cell record missing backend")?;
            CellBackend::parse(b).with_context(|| format!("unknown backend '{b}'"))?
        };
        // hand-authored pre-dynamics records may omit the schedule; every
        // writer since the schedule axis emits it, and the grid hash keeps
        // mixed-schedule artifacts from merging regardless
        let schedule = match doc.get("schedule").as_str() {
            Some(s) => {
                PatternSchedule::parse(s).with_context(|| format!("bad cell schedule '{s}'"))?
            }
            None => PatternSchedule::static_(),
        };
        let epoch_costs = match doc.get("epoch_cost_bits").as_arr() {
            Some(xs) => xs
                .iter()
                .enumerate()
                .map(|(k, x)| {
                    let hex = x
                        .as_str()
                        .with_context(|| format!("epoch_cost_bits[{k}] is not a string"))?;
                    parse_f64_bits_hex(hex)
                        .with_context(|| format!("bad epoch_cost_bits[{k}] '{hex}'"))
                })
                .collect::<Result<Vec<_>>>()?,
            None => Vec::new(),
        };
        let final_cost = match doc.get("final_cost_bits").as_str() {
            Some(hex) => parse_f64_bits_hex(hex)
                .with_context(|| format!("bad final_cost_bits '{hex}'"))?,
            None => {
                // hand-authored records may carry only the decimal field;
                // require it explicitly — a record with *neither* field is
                // corrupt, not saturated. (The serializer writes non-finite
                // costs as JSON null, so an explicit null means +∞.)
                let present = doc
                    .as_obj()
                    .is_some_and(|m| m.contains_key("final_cost"));
                anyhow::ensure!(
                    present,
                    "cell record missing final_cost_bits and final_cost"
                );
                match doc.get("final_cost") {
                    Json::Num(x) => *x,
                    Json::Null => f64::INFINITY,
                    other => bail!(
                        "cell record final_cost must be a number or null, got {other:?}"
                    ),
                }
            }
        };
        let sim = match doc.get("sim") {
            Json::Null => None,
            s => {
                let field = |name: &str| -> Result<f64> {
                    let hex = s
                        .get(name)
                        .as_str()
                        .with_context(|| format!("cell sim digest missing {name}"))?;
                    parse_f64_bits_hex(hex).with_context(|| format!("bad sim {name} '{hex}'"))
                };
                // divergence digest: present iff the sweep ran with
                // --sim-validate (keyed on the authoritative bits field)
                let divergence = match s.get("mean_rel_err_bits") {
                    Json::Null => None,
                    _ => Some(CellDivergence {
                        mean_rel_err: field("mean_rel_err_bits")?,
                        max_server_rel_err: field("max_server_rel_err_bits")?,
                        alarm: s
                            .get("alarm")
                            .as_bool()
                            .context("cell sim divergence missing alarm")?,
                    }),
                };
                // admission-control columns: present iff the sweep ran
                // capped (keyed on the drop counter)
                let (queue_dropped, max_blocking) = match s.get("queue_dropped") {
                    Json::Null => (None, None),
                    d => (
                        Some(d.as_num().context("cell sim queue_dropped is not a number")?
                            as u64),
                        Some(field("max_blocking_bits")?),
                    ),
                };
                Some(CellSim {
                    p50: field("p50_bits")?,
                    p99: field("p99_bits")?,
                    p999: field("p999_bits")?,
                    mean: field("mean_bits")?,
                    divergence,
                    queue_dropped,
                    max_blocking,
                })
            }
        };
        let cache = match doc.get("cache") {
            Json::Null => None,
            c => Some(CellCache {
                hit: c
                    .get("hit")
                    .as_bool()
                    .context("cell cache record missing hit")?,
                iters_saved: c
                    .get("iters_saved")
                    .as_usize()
                    .context("cell cache record missing iters_saved")?,
            }),
        };
        let phi = match doc.get("strategy") {
            Json::Null => None,
            s => Some(Strategy::from_json(s).context("cell strategy")?),
        };
        Ok(CellResult {
            index: doc
                .get("index")
                .as_usize()
                .context("cell record missing index")?,
            cell: SweepCell {
                scenario,
                seed,
                algorithm,
                backend,
                schedule,
            },
            final_cost,
            iterations: doc
                .get("iterations")
                .as_usize()
                .context("cell record missing iterations")?,
            iters_to_1pct: doc
                .get("iters_to_1pct")
                .as_usize()
                .context("cell record missing iters_to_1pct")?,
            wall_seconds: doc.get("wall_seconds").as_num().unwrap_or(0.0),
            epoch_costs,
            sim,
            cache,
            phi,
        })
    }
}

impl ArtifactItem for CellResult {
    fn index(&self) -> usize {
        self.index
    }
    fn describe(&self) -> String {
        GridCell::describe(&self.cell, self.index)
    }
    fn to_json(&self) -> Json {
        CellResult::to_json(self)
    }
    fn from_json(doc: &Json) -> Result<CellResult> {
        CellResult::from_json(doc)
    }
}

impl SweepReport {
    fn from_artifact(a: Artifact<CellResult>) -> SweepReport {
        SweepReport {
            cells: a.items,
            workers: a.workers,
            grid_hash: a.grid_hash,
        }
    }

    fn into_artifact(self) -> Artifact<CellResult> {
        Artifact {
            items: self.cells,
            workers: self.workers,
            grid_hash: self.grid_hash,
        }
    }

    /// Per-`(scenario, algorithm, backend, schedule)` aggregates in
    /// first-appearance order. Dynamic-schedule groups additionally carry
    /// mean/p95 *per-epoch* cost trajectories across their cells.
    pub fn groups(&self) -> Vec<GroupSummary> {
        let mut order: Vec<(String, String, String, String)> = Vec::new();
        let mut buckets: Vec<Vec<&CellResult>> = Vec::new();
        for cell in &self.cells {
            let key = (
                cell.cell.scenario.clone(),
                cell.cell.algorithm.name().to_string(),
                cell.cell.backend.name().to_string(),
                cell.cell.schedule.label(),
            );
            match order.iter().position(|k| *k == key) {
                Some(i) => buckets[i].push(cell),
                None => {
                    order.push(key);
                    buckets.push(vec![cell]);
                }
            }
        }
        order
            .into_iter()
            .zip(buckets)
            .map(|((scenario, algorithm, backend, schedule), cells)| {
                let costs: Vec<f64> = cells.iter().map(|c| c.final_cost).collect();
                let s = summarize(&costs);
                let n = cells.len() as f64;
                // cells of one group share the schedule, hence the epoch
                // count; aggregate each epoch column across seeds
                let epochs = cells
                    .iter()
                    .map(|c| c.epoch_costs.len())
                    .min()
                    .unwrap_or(0);
                let mut epoch_mean_cost = Vec::with_capacity(epochs);
                let mut epoch_p95_cost = Vec::with_capacity(epochs);
                for e in 0..epochs {
                    let col: Vec<f64> = cells.iter().map(|c| c.epoch_costs[e]).collect();
                    let es = summarize(&col);
                    epoch_mean_cost.push(es.mean);
                    epoch_p95_cost.push(es.p95);
                }
                // the grid hash keeps sim and no-sim cells out of one
                // report, so within a group either all cells carry a
                // digest or none do
                let sims: Vec<&CellSim> = cells.iter().filter_map(|c| c.sim.as_ref()).collect();
                let sim_mean = if sims.is_empty() {
                    None
                } else {
                    let k = sims.len() as f64;
                    Some(CellSim {
                        p50: sims.iter().map(|s| s.p50).sum::<f64>() / k,
                        p99: sims.iter().map(|s| s.p99).sum::<f64>() / k,
                        p999: sims.iter().map(|s| s.p999).sum::<f64>() / k,
                        mean: sims.iter().map(|s| s.mean).sum::<f64>() / k,
                        // the per-cell digests keep their own divergence;
                        // the group-level aggregate lives in the dedicated
                        // sim_mean_rel_err / sim_alarms fields below
                        divergence: None,
                        // per-cell drop columns stay per-cell: a mean of
                        // drop totals across seeds measures nothing
                        queue_dropped: None,
                        max_blocking: None,
                    })
                };
                // likewise grid-hash-guarded: either every digest in the
                // group carries a divergence record or none does
                let divs: Vec<CellDivergence> =
                    sims.iter().filter_map(|s| s.divergence).collect();
                let sim_mean_rel_err = if divs.is_empty() {
                    None
                } else {
                    Some(
                        divs.iter().map(|d| d.mean_rel_err).sum::<f64>() / divs.len() as f64,
                    )
                };
                let sim_alarms = divs.iter().filter(|d| d.alarm).count();
                // grid-hash-guarded like the sim digests: within one report
                // either the store-eligible cells all carry a cache record
                // or none does
                let caches: Vec<CellCache> =
                    cells.iter().filter_map(|c| c.cache).collect();
                let cache_hits = if caches.is_empty() {
                    None
                } else {
                    Some((
                        caches.iter().filter(|k| k.hit).count(),
                        caches.iter().map(|k| k.iters_saved).sum(),
                    ))
                };
                GroupSummary {
                    scenario,
                    algorithm,
                    backend,
                    schedule,
                    cells: cells.len(),
                    mean_cost: s.mean,
                    p95_cost: s.p95,
                    mean_iters_to_1pct: cells
                        .iter()
                        .map(|c| c.iters_to_1pct as f64)
                        .sum::<f64>()
                        / n,
                    mean_wall_seconds: cells.iter().map(|c| c.wall_seconds).sum::<f64>() / n,
                    epoch_mean_cost,
                    epoch_p95_cost,
                    sim_mean,
                    sim_mean_rel_err,
                    sim_alarms,
                    cache_hits,
                }
            })
            .collect()
    }

    /// Deterministic identity of the sweep's results: everything except
    /// wall-clock timing and worker/shard metadata, with costs compared
    /// bit-for-bit. Two sweeps of the same spec must produce equal
    /// fingerprints regardless of worker count, shard count, or
    /// retry/re-steal history.
    pub fn fingerprint(&self) -> Vec<CellFingerprint> {
        self.cells
            .iter()
            .map(|c| {
                (
                    c.cell.scenario.clone(),
                    c.cell.seed,
                    c.cell.algorithm.name().to_string(),
                    c.cell.backend.name().to_string(),
                    c.cell.schedule.label(),
                    c.final_cost.to_bits(),
                    c.epoch_costs.iter().map(|x| x.to_bits()).collect(),
                    c.iterations,
                    c.iters_to_1pct,
                    match &c.sim {
                        Some(s) => {
                            let mut bits = vec![
                                s.p50.to_bits(),
                                s.p99.to_bits(),
                                s.p999.to_bits(),
                                s.mean.to_bits(),
                            ];
                            if let Some(d) = &s.divergence {
                                bits.extend([
                                    d.mean_rel_err.to_bits(),
                                    d.max_server_rel_err.to_bits(),
                                    d.alarm as u64,
                                ]);
                            }
                            // capped sweeps measure a different queue:
                            // their drop columns are identity-relevant
                            if let (Some(dropped), Some(mb)) =
                                (s.queue_dropped, s.max_blocking)
                            {
                                bits.extend([dropped, mb.to_bits()]);
                            }
                            bits
                        }
                        None => Vec::new(),
                    },
                )
            })
            .collect()
    }

    /// Paper-style text table of the group aggregates. Reports whose
    /// cells carry a simulated sojourn digest grow three tail-latency
    /// columns (mean across the group's seeds of each cell's simulated
    /// p50/p99/p99.9 request sojourn); closed-loop-validated reports
    /// additionally grow a divergence column (mean relative error of
    /// simulated vs analytic sojourn) and an alarm count.
    pub fn render(&self) -> String {
        let simulated = self.cells.iter().any(|c| c.sim.is_some());
        let validated = self
            .cells
            .iter()
            .any(|c| c.sim.as_ref().is_some_and(|s| s.divergence.is_some()));
        let mut headers = vec![
            "scenario",
            "algo",
            "backend",
            "schedule",
            "cells",
            "mean T",
            "p95 T",
            "iters->1%",
            "mean wall s",
        ];
        if simulated {
            headers.extend(["sim p50", "sim p99", "sim p99.9"]);
        }
        if validated {
            headers.extend(["sim div err", "alarms"]);
        }
        let cached = self.cells.iter().any(|c| c.cache.is_some());
        if cached {
            headers.extend(["cache hits", "iters saved"]);
        }
        let mut t = Table::new(&headers);
        for g in self.groups() {
            let mut row = vec![
                g.scenario,
                g.algorithm,
                g.backend,
                g.schedule,
                g.cells.to_string(),
                fnum(g.mean_cost),
                fnum(g.p95_cost),
                format!("{:.1}", g.mean_iters_to_1pct),
                format!("{:.3}", g.mean_wall_seconds),
            ];
            if simulated {
                match g.sim_mean {
                    Some(s) => row.extend([fnum(s.p50), fnum(s.p99), fnum(s.p999)]),
                    None => row.extend(["-".to_string(), "-".to_string(), "-".to_string()]),
                }
            }
            if validated {
                match g.sim_mean_rel_err {
                    Some(e) => row.extend([fnum(e), g.sim_alarms.to_string()]),
                    None => row.extend(["-".to_string(), "-".to_string()]),
                }
            }
            if cached {
                match g.cache_hits {
                    Some((hits, saved)) => {
                        row.extend([format!("{hits}/{}", g.cells), saved.to_string()])
                    }
                    None => row.extend(["-".to_string(), "-".to_string()]),
                }
            }
            t.row(row);
        }
        t.render()
    }

    /// Machine-readable report (cells + groups). Shard reports written
    /// this way are first-class artifacts: [`SweepReport::from_json`] +
    /// [`SweepReport::merge`] reassemble them.
    pub fn to_json(&self) -> Json {
        let cells: Vec<Json> = self.cells.iter().map(CellResult::to_json).collect();
        let groups: Vec<Json> = self
            .groups()
            .into_iter()
            .map(|g| {
                let mut o = Json::obj();
                o.set("scenario", Json::Str(g.scenario))
                    .set("algorithm", Json::Str(g.algorithm))
                    .set("backend", Json::Str(g.backend))
                    .set("schedule", Json::Str(g.schedule))
                    .set("cells", Json::Num(g.cells as f64))
                    .set("mean_cost", Json::Num(g.mean_cost))
                    .set("p95_cost", Json::Num(g.p95_cost))
                    .set("mean_iters_to_1pct", Json::Num(g.mean_iters_to_1pct))
                    .set("mean_wall_seconds", Json::Num(g.mean_wall_seconds));
                if !g.epoch_mean_cost.is_empty() {
                    o.set("epoch_mean_cost", Json::from_f64_slice(&g.epoch_mean_cost))
                        .set("epoch_p95_cost", Json::from_f64_slice(&g.epoch_p95_cost));
                }
                if let Some(s) = g.sim_mean {
                    o.set("sim_mean_p50", Json::Num(s.p50))
                        .set("sim_mean_p99", Json::Num(s.p99))
                        .set("sim_mean_p999", Json::Num(s.p999))
                        .set("sim_mean_sojourn", Json::Num(s.mean));
                }
                if let Some(e) = g.sim_mean_rel_err {
                    o.set("sim_mean_rel_err", Json::Num(e))
                        .set("sim_alarms", Json::Num(g.sim_alarms as f64));
                }
                if let Some((hits, saved)) = g.cache_hits {
                    o.set("cache_hits", Json::Num(hits as f64))
                        .set("cache_iters_saved", Json::Num(saved as f64));
                }
                o
            })
            .collect();
        let mut doc = Json::obj();
        doc.set("workers", Json::Num(self.workers as f64))
            // hex string: u64 hashes exceed f64's exact-integer range
            .set("grid_hash", Json::Str(u64_hex(self.grid_hash)))
            .set("cells", Json::Arr(cells))
            .set("groups", Json::Arr(groups));
        doc
    }

    /// Parse a report (or shard report) written by [`SweepReport::to_json`]
    /// through the index-verified artifact loader: cells are re-sorted by
    /// global index, a duplicate index is rejected naming the collision,
    /// and the derived `groups` section is ignored (recomputed on demand).
    pub fn from_json(doc: &Json) -> Result<SweepReport> {
        Ok(SweepReport::from_artifact(Artifact::from_json(doc)?))
    }

    /// Merge shard reports back into one full-grid report via the
    /// hash- and index-verified [`Artifact::merge`]: cells are reassembled
    /// by global index, which must form exactly `0..total` (duplicates and
    /// gaps are contextful errors naming the index), and every part must
    /// carry the same [`super::sweep::spec_grid_hash`].
    /// Fingerprint-identical to the single-process run of the same spec.
    pub fn merge(parts: Vec<SweepReport>) -> Result<SweepReport> {
        let parts = parts.into_iter().map(SweepReport::into_artifact).collect();
        Ok(SweepReport::from_artifact(Artifact::merge(parts)?))
    }
}

#[cfg(test)]
mod tests {
    use super::super::sweep::{cell_line, run_sweep, run_sweep_shard, SweepSpec};
    use super::super::RunConfig;
    use super::*;

    fn abilene_spec() -> SweepSpec {
        SweepSpec {
            scenarios: vec!["abilene".into()],
            seeds: vec![1, 2],
            algorithms: vec![Algorithm::Sgp, Algorithm::Lpr],
            backends: vec![CellBackend::Sparse],
            schedules: vec![PatternSchedule::static_()],
            rate_scale: 1.0,
            run: RunConfig::quick(),
            sim: None,
            cache: None,
        }
    }

    #[test]
    fn sweep_runs_and_aggregates() {
        let report = run_sweep(&abilene_spec(), 2).unwrap();
        assert_eq!(report.cells.len(), 4);
        // indices are the canonical grid positions
        assert_eq!(
            report.cells.iter().map(|c| c.index).collect::<Vec<_>>(),
            vec![0, 1, 2, 3]
        );
        let groups = report.groups();
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0].algorithm, "sgp");
        assert_eq!(groups[0].backend, "sparse");
        assert_eq!(groups[0].cells, 2);
        assert!(groups[0].mean_cost.is_finite());
        // Fig. 4 headline on the means: SGP at or below LPR
        assert!(groups[0].mean_cost <= groups[1].mean_cost * 1.001);
        let txt = report.render();
        assert!(txt.contains("abilene"));
        assert!(txt.contains("sgp"));
        let doc = report.to_json();
        assert_eq!(doc.get("cells").as_arr().unwrap().len(), 4);
    }

    #[test]
    fn dynamic_groups_carry_per_epoch_aggregates() {
        let spec = SweepSpec {
            scenarios: vec!["abilene".into()],
            seeds: vec![1, 2],
            algorithms: vec![Algorithm::Sgp],
            backends: vec![CellBackend::Sparse],
            schedules: vec![
                PatternSchedule::static_(),
                PatternSchedule::parse("step:3:1.5").unwrap(),
            ],
            rate_scale: 1.0,
            run: RunConfig::quick(),
            sim: None,
            cache: None,
        };
        let report = run_sweep(&spec, 2).unwrap();
        assert_eq!(report.cells.len(), 4);
        assert!(report.cells[0].epoch_costs.is_empty());
        assert_eq!(report.cells[1].epoch_costs.len(), 3);
        assert_eq!(
            report.cells[1].final_cost.to_bits(),
            report.cells[1].epoch_costs[2].to_bits(),
            "a dynamic cell reports its last epoch's cost"
        );
        let groups = report.groups();
        assert_eq!(groups.len(), 2, "schedules must not pool in one group");
        assert_eq!(groups[0].schedule, "static");
        assert!(groups[0].epoch_mean_cost.is_empty());
        assert_eq!(groups[1].schedule, "step:3:1.5");
        // per-epoch trajectories aggregate the two seeds epoch by epoch
        assert_eq!(groups[1].epoch_mean_cost.len(), 3);
        assert_eq!(groups[1].epoch_p95_cost.len(), 3);
        let dynamic: Vec<&CellResult> = report
            .cells
            .iter()
            .filter(|c| !c.epoch_costs.is_empty())
            .collect();
        assert_eq!(dynamic.len(), 2);
        for e in 0..3 {
            let mean = (dynamic[0].epoch_costs[e] + dynamic[1].epoch_costs[e]) / 2.0;
            assert!(
                (groups[1].epoch_mean_cost[e] - mean).abs() <= 1e-12 * mean.abs(),
                "epoch {e} mean drifted"
            );
            assert!(
                groups[1].epoch_p95_cost[e]
                    >= dynamic[0].epoch_costs[e].min(dynamic[1].epoch_costs[e])
            );
        }
        // the trajectories survive the JSON report
        let doc = report.to_json();
        let gs = doc.get("groups").as_arr().unwrap();
        let g1 = gs
            .iter()
            .find(|g| g.get("schedule").as_str() == Some("step:3:1.5"))
            .unwrap();
        assert_eq!(g1.get("epoch_mean_cost").as_arr().unwrap().len(), 3);
        assert_eq!(g1.get("epoch_p95_cost").as_arr().unwrap().len(), 3);
        // and the fingerprint round-trips
        let back = SweepReport::from_json(&Json::parse(&doc.pretty()).unwrap()).unwrap();
        assert_eq!(back.fingerprint(), report.fingerprint());
    }

    #[test]
    fn in_process_shards_merge_to_the_full_report() {
        let spec = abilene_spec();
        let whole = run_sweep(&spec, 2).unwrap();
        for count in [1usize, 2, 4] {
            let parts: Vec<SweepReport> = (0..count)
                .map(|k| run_sweep_shard(&spec, k, count, 2).unwrap())
                .collect();
            let merged = SweepReport::merge(parts).unwrap();
            assert_eq!(
                merged.fingerprint(),
                whole.fingerprint(),
                "{count} shard(s) drifted from the single-process run"
            );
        }
    }

    #[test]
    fn merge_rejects_gaps_and_duplicates() {
        let spec = abilene_spec();
        let a = run_sweep_shard(&spec, 0, 2, 1).unwrap();
        let b = run_sweep_shard(&spec, 1, 2, 1).unwrap();
        // missing shard
        let err = SweepReport::merge(vec![a.clone()]).unwrap_err().to_string();
        assert!(err.contains("missing cell index"), "{err}");
        // duplicate shard: the error names the colliding global index
        let err = SweepReport::merge(vec![a.clone(), a.clone(), b.clone()])
            .unwrap_err()
            .to_string();
        assert!(err.contains("duplicate"), "{err}");
        assert!(err.contains("index 0"), "{err}");
        // correct merge still fine
        assert!(SweepReport::merge(vec![a, b]).is_ok());
    }

    #[test]
    fn merge_rejects_shards_of_different_specs() {
        // equal-sized grids from different specs: index coverage alone
        // would pass, the grid hash must not
        let spec_a = abilene_spec();
        let spec_b = SweepSpec {
            seeds: vec![1, 3],
            ..abilene_spec()
        };
        let a = run_sweep_shard(&spec_a, 0, 2, 1).unwrap();
        let b = run_sweep_shard(&spec_b, 1, 2, 1).unwrap();
        let err = SweepReport::merge(vec![a, b]).unwrap_err().to_string();
        assert!(err.contains("different sweep specs"), "{err}");
    }

    #[test]
    fn loading_an_artifact_with_duplicate_indices_is_rejected() {
        // an overlapping shard split can produce one artifact carrying the
        // same global index twice; first-write-wins loading would mask it
        let a = run_sweep_shard(&abilene_spec(), 0, 2, 1).unwrap();
        let mut doc = a.to_json();
        let mut cells = doc.get("cells").as_arr().unwrap().to_vec();
        cells.push(cells[0].clone());
        doc.set("cells", Json::Arr(cells));
        let err = SweepReport::from_json(&Json::parse(&doc.pretty()).unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("twice"), "{err}");
        assert!(err.contains("index 0"), "{err}");
    }

    #[test]
    fn report_json_roundtrip_is_bit_exact() {
        // Hand-built report with awkward values (∞ cost from a saturated
        // cell): serde must round-trip the fingerprint exactly even though
        // JSON itself cannot represent ∞.
        let mk = |index: usize, cost: f64| CellResult {
            index,
            cell: SweepCell {
                scenario: "abilene".into(),
                seed: 1 + index as u64,
                algorithm: Algorithm::Sgp,
                backend: CellBackend::Native,
                schedule: PatternSchedule::parse("step:2:1.5").unwrap(),
            },
            final_cost: cost,
            iterations: 5,
            iters_to_1pct: 2,
            wall_seconds: 0.25,
            epoch_costs: vec![123.5, cost],
            // a digest with awkward values: serde must carry it bit-exactly
            sim: Some(CellSim {
                p50: 0.125,
                p99: cost,
                p999: f64::INFINITY,
                mean: 0.1 + 0.2,
                divergence: Some(CellDivergence {
                    mean_rel_err: 0.1 + 0.2,
                    max_server_rel_err: f64::INFINITY,
                    alarm: index == 1,
                }),
                queue_dropped: Some(7 + index as u64),
                max_blocking: Some(0.1 + 0.2),
            }),
            cache: Some(CellCache {
                hit: index == 0,
                iters_saved: 40 * (1 - index),
            }),
            phi: Some(Strategy::local_compute_init(
                &crate::model::network::testnet::diamond(true),
            )),
        };
        let report = SweepReport {
            cells: vec![mk(0, 123.456_789_012_345), mk(1, f64::INFINITY)],
            workers: 3,
            grid_hash: 0xdead_beef_0042_1337,
        };
        let text = report.to_json().pretty();
        let back = SweepReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(report.fingerprint(), back.fingerprint());
        assert!(back.cells[1].final_cost.is_infinite());
        assert_eq!(back.workers, 3);
        assert_eq!(back.grid_hash, report.grid_hash);
        // the sojourn digest round-trips bit-exactly, ∞ included, and the
        // text table grows the tail columns for simulated reports
        let s = back.cells[1].sim.expect("sim digest lost in round-trip");
        assert_eq!(s.p999.to_bits(), f64::INFINITY.to_bits());
        assert_eq!(s.mean.to_bits(), (0.1f64 + 0.2).to_bits());
        // the divergence digest round-trips too, alarm flag included
        let d = s.divergence.expect("divergence digest lost in round-trip");
        assert_eq!(d.mean_rel_err.to_bits(), (0.1f64 + 0.2).to_bits());
        assert_eq!(d.max_server_rel_err.to_bits(), f64::INFINITY.to_bits());
        assert!(d.alarm);
        assert!(!back.cells[0].sim.unwrap().divergence.unwrap().alarm);
        // the admission-control columns round-trip bit-exactly too
        assert_eq!(s.queue_dropped, Some(8));
        assert_eq!(s.max_blocking.unwrap().to_bits(), (0.1f64 + 0.2).to_bits());
        // the cache record and the shipped strategy round-trip too
        assert_eq!(
            back.cells[0].cache,
            Some(CellCache {
                hit: true,
                iters_saved: 40
            })
        );
        assert_eq!(
            back.cells[1].cache,
            Some(CellCache {
                hit: false,
                iters_saved: 0
            })
        );
        assert_eq!(back.cells[0].phi, report.cells[0].phi);
        let txt = report.render();
        assert!(txt.contains("sim p99"), "{txt}");
        assert!(txt.contains("sim div err"), "{txt}");
        assert!(txt.contains("alarms"), "{txt}");
        assert!(txt.contains("cache hits"), "{txt}");
        assert!(txt.contains("iters saved"), "{txt}");
        // the group surface carries the validation aggregate
        let doc = Json::parse(&text).unwrap();
        let g0 = &doc.get("groups").as_arr().unwrap()[0];
        assert!(g0.get("sim_mean_rel_err").as_num().is_some());
        assert_eq!(g0.get("sim_alarms").as_num(), Some(1.0));
        // ... and the store aggregate: 1 hit across the group, 40 saved
        assert_eq!(g0.get("cache_hits").as_num(), Some(1.0));
        assert_eq!(g0.get("cache_iters_saved").as_num(), Some(40.0));
    }

    #[test]
    fn corrupt_cell_records_are_rejected_not_defaulted() {
        let base = r#"{"index":0,"scenario":"abilene","seed":1,"algorithm":"sgp",
                       "backend":"sparse","iterations":3,"iters_to_1pct":1,
                       "wall_seconds":0.1"#;
        // neither final_cost_bits nor final_cost: corrupt, not saturated
        let doc = Json::parse(&format!("{base}}}")).unwrap();
        let err = CellResult::from_json(&doc).unwrap_err().to_string();
        assert!(err.contains("final_cost"), "{err}");
        // an explicit null cost (the serializer's spelling of ∞) still loads
        let doc = Json::parse(&format!("{base},\"final_cost\":null}}")).unwrap();
        assert!(CellResult::from_json(&doc).unwrap().final_cost.is_infinite());
        // a missing backend is an error too (every writer emits it)
        let doc = Json::parse(
            r#"{"index":0,"scenario":"abilene","seed":1,"algorithm":"sgp",
                "final_cost":2.5,"iterations":3,"iters_to_1pct":1,"wall_seconds":0.1}"#,
        )
        .unwrap();
        let err = CellResult::from_json(&doc).unwrap_err().to_string();
        assert!(err.contains("backend"), "{err}");
    }

    #[test]
    fn cell_protocol_lines_roundtrip_bit_exactly() {
        let cell = CellResult {
            index: 7,
            cell: SweepCell {
                scenario: "connected-er".into(),
                seed: 3,
                algorithm: Algorithm::Gp,
                backend: CellBackend::Sparse,
                schedule: PatternSchedule::parse("bursty:4:2").unwrap(),
            },
            final_cost: f64::INFINITY,
            iterations: 80,
            iters_to_1pct: 80,
            wall_seconds: 1.5,
            epoch_costs: vec![10.0, f64::INFINITY, 9.5, f64::INFINITY],
            sim: None,
            cache: Some(CellCache {
                hit: true,
                iters_saved: 80,
            }),
            phi: Some(Strategy::local_compute_init(
                &crate::model::network::testnet::diamond(true),
            )),
        };
        let doc = Json::parse(&cell_line(&cell)).unwrap();
        assert_eq!(doc.get("type").as_str(), Some("cell"));
        let back = CellResult::from_json(&doc).unwrap();
        assert_eq!(back.index, 7);
        assert_eq!(back.cell, cell.cell);
        assert_eq!(back.final_cost.to_bits(), cell.final_cost.to_bits());
        // the cache record and strategy travel the protocol too
        assert_eq!(back.cache, cell.cache);
        assert_eq!(back.phi, cell.phi);
        // per-epoch finals travel the protocol bit-exactly, ∞ included
        assert_eq!(
            back.epoch_costs.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            cell.epoch_costs.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }
}
