//! Experiment metrics (§V): the travel-distance statistics `L_data` /
//! `L_result` of Fig. 5d and cost decompositions.

use crate::model::flows::FlowState;
use crate::model::network::Network;

/// Flow-weighted average hop counts.
///
/// Under the flow model, the average number of hops a data packet travels
/// equals total data link flow divided by total exogenous input rate
/// (every hop of every packet contributes its rate to exactly one link);
/// likewise for results with the total result generation rate `Σ a_m g`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TravelDistance {
    pub l_data: f64,
    pub l_result: f64,
}

pub fn travel_distance(net: &Network, flows: &FlowState) -> TravelDistance {
    let mut data_flow = 0.0;
    let mut res_flow = 0.0;
    for s in 0..net.s() {
        data_flow += flows.f_minus[s].iter().sum::<f64>();
        res_flow += flows.f_plus[s].iter().sum::<f64>();
    }
    let data_rate: f64 = (0..net.s()).map(|s| net.task_input(s)).sum();
    let res_rate: f64 = (0..net.s())
        .map(|s| net.a_of(s) * flows.g[s].iter().sum::<f64>())
        .sum();
    TravelDistance {
        l_data: if data_rate > 0.0 { data_flow / data_rate } else { 0.0 },
        l_result: if res_rate > 0.0 { res_flow / res_rate } else { 0.0 },
    }
}

/// First iteration (1-based) whose cost is within `frac` of the final
/// cost — the generalized convergence-speed metric behind
/// [`iters_to_1pct`] and the dynamic engine's per-epoch re-convergence
/// counts ([`super::dynamics`]).
///
/// Non-finite trajectories are handled conservatively: a run that never
/// reaches a finite final cost "converges" only at its last iteration
/// (`costs.len()`), never at iteration 1 via `x <= ∞`.
pub fn iters_to_within(costs: &[f64], frac: f64) -> usize {
    if costs.is_empty() {
        return 0;
    }
    let fin = costs[costs.len() - 1];
    if !fin.is_finite() {
        return costs.len();
    }
    let thresh = fin * (1.0 + frac);
    costs
        .iter()
        .position(|&c| c <= thresh)
        .map(|p| p + 1)
        .unwrap_or(costs.len())
}

/// First iteration (1-based) whose cost is within 1% of the final cost —
/// the convergence-speed metric of Fig. 5b, shared by [`super::runner`]
/// and the [`super::sweep`] aggregator.
pub fn iters_to_1pct(costs: &[f64]) -> usize {
    iters_to_within(costs, 0.01)
}

/// Transient regret of a re-convergence trajectory: the area between the
/// cost curve and its settled value, `Σ_t max(0, T_t − settled)` over the
/// finite entries. This is the price paid for a workload shift while the
/// optimizer catches up — the dynamic engine records it per epoch. A
/// non-finite `settled` (a run that never recovered) yields `+∞`.
pub fn transient_regret(costs: &[f64], settled: f64) -> f64 {
    if !settled.is_finite() {
        return f64::INFINITY;
    }
    costs
        .iter()
        .filter(|c| c.is_finite())
        .map(|&c| (c - settled).max(0.0))
        .sum()
}

/// Cost decomposition: communication vs computation share of `T`.
#[derive(Clone, Copy, Debug)]
pub struct CostBreakdown {
    pub communication: f64,
    pub computation: f64,
}

pub fn cost_breakdown(net: &Network, flows: &FlowState) -> CostBreakdown {
    let communication: f64 = (0..net.e())
        .map(|e| net.link_cost[e].value(flows.link_flow[e]))
        .sum();
    let computation: f64 = (0..net.n())
        .map(|i| net.comp_cost[i].value(flows.workload[i]))
        .sum();
    CostBreakdown {
        communication,
        computation,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::flows::compute_flows;
    use crate::model::network::testnet::diamond;
    use crate::model::strategy::Strategy;

    #[test]
    fn local_compute_means_zero_data_distance() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let flows = compute_flows(&net, &phi).unwrap();
        let td = travel_distance(&net, &flows);
        assert_eq!(td.l_data, 0.0);
        // results travel SP distance 0 -> 3 = 2 hops
        assert!((td.l_result - 2.0).abs() < 1e-9, "l_result {}", td.l_result);
    }

    #[test]
    fn compute_at_dest_means_zero_result_distance() {
        let net = diamond(true);
        let phi = Strategy::compute_at_dest_init(&net);
        let flows = compute_flows(&net, &phi).unwrap();
        let td = travel_distance(&net, &flows);
        assert!((td.l_data - 2.0).abs() < 1e-9);
        assert_eq!(td.l_result, 0.0);
    }

    #[test]
    fn iters_to_1pct_basic_and_nonfinite() {
        assert_eq!(iters_to_1pct(&[]), 0);
        assert_eq!(iters_to_1pct(&[5.0]), 1);
        // 10, 2, 1.005, 1.0: first within 1% of 1.0 is index 2 -> iter 3
        assert_eq!(iters_to_1pct(&[10.0, 2.0, 1.005, 1.0]), 3);
        // a saturated run must not "converge at iteration 1"
        assert_eq!(iters_to_1pct(&[f64::INFINITY, f64::INFINITY]), 2);
        assert_eq!(iters_to_1pct(&[10.0, f64::NAN]), 2);
        // early saturation followed by finite descent is fine
        assert_eq!(iters_to_1pct(&[f64::INFINITY, 2.0, 1.0]), 3);
    }

    #[test]
    fn iters_to_within_generalizes_1pct() {
        let costs = [10.0, 2.0, 1.005, 1.0];
        assert_eq!(iters_to_within(&costs, 0.01), iters_to_1pct(&costs));
        // a looser band converges earlier, a tighter one later
        assert_eq!(iters_to_within(&costs, 1.5), 2);
        assert_eq!(iters_to_within(&costs, 0.001), 4);
        assert_eq!(iters_to_within(&[], 0.01), 0);
    }

    #[test]
    fn transient_regret_measures_the_catchup_area() {
        assert_eq!(transient_regret(&[12.0, 11.0, 10.0], 10.0), 3.0);
        // flat trajectories pay nothing
        assert_eq!(transient_regret(&[10.0, 10.0], 10.0), 0.0);
        // dips below settled never give negative credit
        assert_eq!(transient_regret(&[12.0, 9.0, 10.0], 10.0), 2.0);
        // saturated iterations are excluded, unrecovered runs are +∞
        assert_eq!(transient_regret(&[f64::INFINITY, 11.0, 10.0], 10.0), 1.0);
        assert!(transient_regret(&[f64::INFINITY], f64::INFINITY).is_infinite());
    }

    #[test]
    fn breakdown_sums_to_total() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let flows = compute_flows(&net, &phi).unwrap();
        let bd = cost_breakdown(&net, &flows);
        assert!(
            (bd.communication + bd.computation - flows.total_cost).abs() < 1e-9
        );
        assert!(bd.communication > 0.0);
        assert!(bd.computation > 0.0);
    }
}
