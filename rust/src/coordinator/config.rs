//! JSON experiment configuration for the `cecflow run` subcommand.
//!
//! Example:
//! ```json
//! {
//!   "scenario": "geant",
//!   "seed": 42,
//!   "algorithm": "sgp",
//!   "max_iters": 200,
//!   "rate_scale": 1.0,
//!   "schedule": "sync"
//! }
//! ```

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    Sgp,
    Gp,
    Spoo,
    Lcor,
    Lpr,
}

impl Algorithm {
    pub fn parse(name: &str) -> Option<Algorithm> {
        Some(match name.to_ascii_lowercase().as_str() {
            "sgp" => Algorithm::Sgp,
            "gp" => Algorithm::Gp,
            "spoo" => Algorithm::Spoo,
            "lcor" => Algorithm::Lcor,
            "lpr" => Algorithm::Lpr,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Sgp => "sgp",
            Algorithm::Gp => "gp",
            Algorithm::Spoo => "spoo",
            Algorithm::Lcor => "lcor",
            Algorithm::Lpr => "lpr",
        }
    }

    pub fn all() -> &'static [Algorithm] {
        &[
            Algorithm::Sgp,
            Algorithm::Gp,
            Algorithm::Spoo,
            Algorithm::Lcor,
            Algorithm::Lpr,
        ]
    }

    /// Algorithms the dynamic task-pattern engine
    /// ([`crate::coordinator::dynamics`]) can re-optimize across epochs:
    /// iterative optimizers that start from the plain all-local point
    /// (SGP and GP). The one-shot LPR has no notion of re-convergence,
    /// and SPOO/LCOR construct their own restricted starting points. The
    /// sweep grid builder skips non-static schedules for everything else.
    pub fn supports_dynamic(&self) -> bool {
        matches!(self, Algorithm::Sgp | Algorithm::Gp)
    }

    /// Algorithms the strategy store ([`crate::coordinator::store`]) can
    /// warm-start: the iterative optimizers that accept an *arbitrary*
    /// feasible initial point. Same set as [`Algorithm::supports_dynamic`]
    /// today, but named separately because the contracts differ — the
    /// dynamic engine needs re-convergence across epochs, the store needs
    /// [`crate::coordinator::run_algorithm_warm`] to accept a cached
    /// strategy as the initial point. SPOO/LCOR construct their own
    /// restricted starting points and the one-shot LPR has no iteration
    /// to warm, so sweep cells for those never consult the store.
    pub fn supports_warm_start(&self) -> bool {
        matches!(self, Algorithm::Sgp | Algorithm::Gp)
    }

    /// Algorithms whose outcome carries a concrete routing/offloading
    /// strategy for the request-level simulator
    /// ([`crate::sim::tasks::simulate`]) to walk. The one-shot LPR
    /// computes a *bound*, not a strategy, so sweep cells with
    /// tail-latency columns enabled must exclude it.
    pub fn supports_simulation(&self) -> bool {
        !matches!(self, Algorithm::Lpr)
    }
}

/// Dense-evaluation route for one sweep cell's SGP run (per-cell backend
/// selection in [`crate::coordinator::SweepSpec`]).
///
/// Only SGP has a dense path ([`crate::algo::Sgp::step_dense`]); the grid
/// builder skips non-SGP × non-[`CellBackend::Sparse`] combinations, so a
/// sweep over `--backends sparse,native` prices every algorithm on the
/// sparse path and SGP additionally through the native dense backend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CellBackend {
    /// The sparse Gauss–Seidel path (`Sgp::step` / `run_algorithm`) — the
    /// default, and the only route for the non-SGP baselines.
    Sparse,
    /// `Sgp::step_dense` on [`crate::runtime::NativeBackend`]: exercises
    /// the batched safeguard ladder (`evaluate_batch`) in pure-rust f64.
    Native,
    /// `Sgp::step_dense` on the PJRT `DenseEvaluator` (needs a build with
    /// `--features pjrt` plus `make artifacts`).
    Pjrt,
}

impl CellBackend {
    pub fn parse(name: &str) -> Option<CellBackend> {
        Some(match name.to_ascii_lowercase().as_str() {
            "sparse" => CellBackend::Sparse,
            "native" => CellBackend::Native,
            "pjrt" | "xla" => CellBackend::Pjrt,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            CellBackend::Sparse => "sparse",
            CellBackend::Native => "native",
            CellBackend::Pjrt => "pjrt",
        }
    }

    pub fn all() -> &'static [CellBackend] {
        &[CellBackend::Sparse, CellBackend::Native, CellBackend::Pjrt]
    }
}

/// Update schedule for the optimization loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    /// All nodes update each iteration (Algorithm 1's synchronized form).
    Sync,
    /// One random (node, task, plane) per update (Theorem 2).
    Async,
    /// Synchronous iterations with flows/marginals on the XLA data plane.
    Accelerated,
}

impl Schedule {
    pub fn parse(name: &str) -> Option<Schedule> {
        Some(match name.to_ascii_lowercase().as_str() {
            "sync" => Schedule::Sync,
            "async" => Schedule::Async,
            "accelerated" | "xla" => Schedule::Accelerated,
            _ => return None,
        })
    }
}

// ---------------------------------------------------------------------------
// CLI list parsers (the sweep grid axes)
// ---------------------------------------------------------------------------

/// Largest seed accepted from the CLI: seeds are reported in JSON, whose
/// numbers are f64, so anything above 2^53 would silently collide with a
/// neighbor in `sweep.json`.
pub const MAX_SEED: u64 = 1 << 53;

/// Parse a comma-separated scenario list (`"abilene,connected-er"`).
pub fn parse_scenarios(s: &str) -> Vec<String> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(str::to_string)
        .collect()
}

/// Parse a comma-separated seed list (`"1,2,3"`) or an inclusive range
/// (`"1..8"`). Seeds above 2^53 are rejected (not representable in the
/// JSON report).
pub fn parse_seeds(s: &str) -> Result<Vec<u64>> {
    let check = |seed: u64| -> Result<u64> {
        anyhow::ensure!(
            seed <= MAX_SEED,
            "seed {seed} exceeds 2^53 and would lose precision in the JSON report"
        );
        Ok(seed)
    };
    if let Some((lo, hi)) = s.split_once("..") {
        let lo: u64 = lo.trim().parse().context("seed range start")?;
        let hi: u64 = check(hi.trim().parse().context("seed range end")?)?;
        anyhow::ensure!(lo <= hi, "empty seed range {lo}..{hi}");
        return Ok((lo..=hi).collect());
    }
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| {
            t.parse::<u64>()
                .with_context(|| format!("bad seed '{t}'"))
                .and_then(check)
        })
        .collect()
}

/// Parse a strictly-positive finite flag value — shared by
/// `simulate --validate`, `simulate --reoptimize-every` and
/// `sweep --sim-validate`, which all reject zero/negative/non-finite
/// tolerances and intervals.
pub fn parse_positive_f64(flag: &str, raw: &str) -> Result<f64> {
    let x: f64 = raw
        .parse()
        .with_context(|| format!("{flag} expects a number, got '{raw}'"))?;
    anyhow::ensure!(
        x.is_finite() && x > 0.0,
        "{flag} must be finite and positive, got {raw}"
    );
    Ok(x)
}

/// Parse a comma-separated algorithm list (`"sgp,gp,lpr"`).
pub fn parse_algorithms(s: &str) -> Result<Vec<Algorithm>> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| Algorithm::parse(t).with_context(|| format!("unknown algorithm '{t}'")))
        .collect()
}

/// Parse a comma-separated backend list (`"sparse,native"`).
pub fn parse_backends(s: &str) -> Result<Vec<CellBackend>> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| CellBackend::parse(t).with_context(|| format!("unknown backend '{t}'")))
        .collect()
}

/// A full experiment configuration.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub scenario: String,
    pub seed: u64,
    pub algorithm: Algorithm,
    pub max_iters: usize,
    pub rate_scale: f64,
    pub schedule: Schedule,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            scenario: "connected-er".to_string(),
            seed: 42,
            algorithm: Algorithm::Sgp,
            max_iters: 200,
            rate_scale: 1.0,
            schedule: Schedule::Sync,
        }
    }
}

impl ExperimentConfig {
    pub fn from_json(doc: &Json) -> Result<ExperimentConfig> {
        let mut cfg = ExperimentConfig::default();
        if let Some(s) = doc.get("scenario").as_str() {
            cfg.scenario = s.to_string();
        }
        if let Some(n) = doc.get("seed").as_num() {
            cfg.seed = n as u64;
        }
        if let Some(a) = doc.get("algorithm").as_str() {
            cfg.algorithm =
                Algorithm::parse(a).with_context(|| format!("unknown algorithm '{a}'"))?;
        }
        if let Some(n) = doc.get("max_iters").as_usize() {
            cfg.max_iters = n;
        }
        if let Some(x) = doc.get("rate_scale").as_num() {
            if x <= 0.0 {
                bail!("rate_scale must be positive");
            }
            cfg.rate_scale = x;
        }
        if let Some(s) = doc.get("schedule").as_str() {
            cfg.schedule =
                Schedule::parse(s).with_context(|| format!("unknown schedule '{s}'"))?;
        }
        Ok(cfg)
    }

    pub fn load(path: &Path) -> Result<ExperimentConfig> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        let doc = Json::parse(&text).context("parsing config JSON")?;
        Self::from_json(&doc)
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("scenario", Json::Str(self.scenario.clone()))
            .set("seed", Json::Num(self.seed as f64))
            .set("algorithm", Json::Str(self.algorithm.name().to_string()))
            .set("max_iters", Json::Num(self.max_iters as f64))
            .set("rate_scale", Json::Num(self.rate_scale))
            .set(
                "schedule",
                Json::Str(
                    match self.schedule {
                        Schedule::Sync => "sync",
                        Schedule::Async => "async",
                        Schedule::Accelerated => "accelerated",
                    }
                    .to_string(),
                ),
            );
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let doc = Json::parse(
            r#"{"scenario":"geant","seed":7,"algorithm":"lpr",
                "max_iters":50,"rate_scale":1.2,"schedule":"async"}"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&doc).unwrap();
        assert_eq!(cfg.scenario, "geant");
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.algorithm, Algorithm::Lpr);
        assert_eq!(cfg.max_iters, 50);
        assert_eq!(cfg.rate_scale, 1.2);
        assert_eq!(cfg.schedule, Schedule::Async);
    }

    #[test]
    fn defaults_fill_missing() {
        let cfg = ExperimentConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(cfg.algorithm, Algorithm::Sgp);
        assert_eq!(cfg.schedule, Schedule::Sync);
    }

    #[test]
    fn rejects_bad_values() {
        assert!(ExperimentConfig::from_json(
            &Json::parse(r#"{"algorithm":"zzz"}"#).unwrap()
        )
        .is_err());
        assert!(ExperimentConfig::from_json(
            &Json::parse(r#"{"rate_scale":-1}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn json_roundtrip() {
        let cfg = ExperimentConfig::default();
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.scenario, cfg.scenario);
        assert_eq!(back.algorithm, cfg.algorithm);
    }

    #[test]
    fn algorithm_parse_roundtrip() {
        for a in Algorithm::all() {
            assert_eq!(Algorithm::parse(a.name()), Some(*a));
        }
    }

    #[test]
    fn list_parsers() {
        assert_eq!(parse_scenarios("a, b,"), vec!["a", "b"]);
        assert_eq!(parse_seeds("1, 2,3").unwrap(), vec![1, 2, 3]);
        assert_eq!(parse_seeds("4..6").unwrap(), vec![4, 5, 6]);
        assert!(parse_seeds("9..2").is_err());
        assert!(parse_seeds("x").is_err());
        // seeds past 2^53 would alias in the f64-backed JSON report
        assert!(parse_seeds("9007199254740993").is_err());
        assert_eq!(
            parse_algorithms("sgp,lpr").unwrap(),
            vec![Algorithm::Sgp, Algorithm::Lpr]
        );
        assert!(parse_algorithms("sgp,zzz").is_err());
        assert_eq!(
            parse_backends("sparse, native").unwrap(),
            vec![CellBackend::Sparse, CellBackend::Native]
        );
        assert!(parse_backends("sparse,zzz").is_err());
    }

    #[test]
    fn cell_backend_parse_roundtrip() {
        for b in CellBackend::all() {
            assert_eq!(CellBackend::parse(b.name()), Some(*b));
        }
        assert_eq!(CellBackend::parse("XLA"), Some(CellBackend::Pjrt));
        assert_eq!(CellBackend::parse("zzz"), None);
    }
}
