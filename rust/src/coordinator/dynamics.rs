//! Dynamic task-pattern engine: time-varying scenarios and warm-start
//! re-optimization.
//!
//! The paper's distributed algorithm is "adaptive to changes in task
//! pattern" (§IV): after a workload shift the current strategy is still a
//! feasible point, so re-optimizing from it (warm start) should
//! re-converge in far fewer iterations than starting from scratch. This
//! module makes that claim testable:
//!
//! * [`PatternSchedule`] — a deterministic recipe that mutates a base
//!   scenario's *task pattern* (input rates, task sources/destinations) at
//!   epoch boundaries: step change, bursty on/off, diurnal ramp,
//!   source/destination churn, or compounding rate rescale. Epoch `e` of a
//!   schedule is a pure function of `(base network, seed, e)` — the same
//!   cell is bitwise reproducible on any worker or shard.
//! * [`AdaptiveRunner`] — re-optimizes every epoch either **warm-started**
//!   from the previous epoch's converged strategy
//!   ([`Strategy::retarget`]) or **cold-started** from the all-local
//!   point, over the sparse, native-dense or PJRT evaluation routes. The
//!   epoch-to-epoch strategy carry rides the content-addressed strategy
//!   store ([`super::store`]): by default a private in-memory carrier,
//!   or — under `cecflow dynamic --cache-dir` — a filesystem store whose
//!   verified entries let a re-run adopt previously converged epochs
//!   without re-solving, and whose traces ship the per-epoch converged
//!   strategies ([`EpochTrace::phi`]).
//! * [`EpochTrace`] / [`DynamicTrace`] — per-epoch cost trajectories,
//!   iterations to re-convergence, iters-to-1%, and the transient regret
//!   paid between the shift and the new steady state.
//!
//! The adaptivity contract (warm re-converges in ≤ the cold iteration
//! count after every shift; an epoch that changes nothing costs exactly
//! the convergence check) is pinned by `rust/tests/adaptive_runner.rs`,
//! and schedules are a first-class sweep axis
//! ([`super::sweep::SweepSpec::schedules`], CLI `cecflow sweep
//! --schedules` / `cecflow dynamic`).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::algo::OptWorkspace;
use crate::model::cost::CostFn;
use crate::model::flows::compute_flows;
use crate::model::network::Network;
use crate::model::strategy::Strategy;
use crate::util::json::Json;
use crate::util::rng::Pcg;

use super::exec::grid::{Grid, GridCell, GridHasher};
use super::exec::pool;
use super::store::{self, FsStore, MemStore, StoredRun, StrategyStore};
use super::{build_scenario_network, metrics, AlgoOutcome, Algorithm, CellBackend, RunConfig};

/// The five time-varying task-pattern families, plus the degenerate
/// `Static` (one epoch, no mutation — the classic fixed-scenario run).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScheduleKind {
    /// No change: a single epoch on the base pattern.
    Static,
    /// One permanent shift: epochs `1..` run at `magnitude ×` the base
    /// input rates (epochs after the first change nothing — the
    /// zero-extra-iterations case of the adaptivity suite).
    Step,
    /// On/off burst: odd epochs at `magnitude ×`, even epochs at the base
    /// rates.
    Bursty,
    /// Smooth diurnal ramp: epoch `e` runs at
    /// `1 + (magnitude − 1)·½(1 − cos(2πe/epochs))` × the base rates
    /// (one full day over the schedule; epoch 0 is the base).
    Diurnal,
    /// Source/destination churn: each epoch, a `magnitude` fraction of
    /// the tasks (at least one) moves — new random destination, sources
    /// relocated to fresh nodes carrying the same rates. Total demand is
    /// preserved; *where* it enters and exits shifts.
    Churn,
    /// Compounding growth: epoch `e` runs at `magnitude^e ×` the base
    /// rates.
    Rescale,
}

impl ScheduleKind {
    pub fn parse(name: &str) -> Option<ScheduleKind> {
        Some(match name.to_ascii_lowercase().as_str() {
            "static" | "none" => ScheduleKind::Static,
            "step" => ScheduleKind::Step,
            "bursty" | "burst" => ScheduleKind::Bursty,
            "diurnal" => ScheduleKind::Diurnal,
            "churn" => ScheduleKind::Churn,
            "rescale" => ScheduleKind::Rescale,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ScheduleKind::Static => "static",
            ScheduleKind::Step => "step",
            ScheduleKind::Bursty => "bursty",
            ScheduleKind::Diurnal => "diurnal",
            ScheduleKind::Churn => "churn",
            ScheduleKind::Rescale => "rescale",
        }
    }

    pub fn all() -> &'static [ScheduleKind] {
        &[
            ScheduleKind::Static,
            ScheduleKind::Step,
            ScheduleKind::Bursty,
            ScheduleKind::Diurnal,
            ScheduleKind::Churn,
            ScheduleKind::Rescale,
        ]
    }

    /// Default shift magnitude when the label omits one: rate multipliers
    /// for the scaling kinds, the churned task fraction for `Churn`.
    fn default_magnitude(&self) -> f64 {
        match self {
            ScheduleKind::Static => 1.0,
            ScheduleKind::Step => 1.5,
            ScheduleKind::Bursty => 2.0,
            ScheduleKind::Diurnal => 2.0,
            ScheduleKind::Churn => 0.25,
            ScheduleKind::Rescale => 1.25,
        }
    }

    fn default_epochs(&self) -> usize {
        if *self == ScheduleKind::Static {
            1
        } else {
            3
        }
    }
}

/// A fully-specified task-pattern schedule: kind + epoch count + shift
/// magnitude. The magnitude is stored as exact f64 bits so schedules have
/// total equality and can sit inside sweep cells / fingerprints; the
/// canonical string form (`step:3:1.5`, or just `static`) round-trips
/// through [`PatternSchedule::parse`] and is what travels on the CLI, in
/// report JSON and in the shard protocol.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PatternSchedule {
    pub kind: ScheduleKind,
    epochs: usize,
    magnitude_bits: u64,
}

impl PatternSchedule {
    /// The no-op schedule: one epoch on the unmodified scenario.
    pub fn static_() -> PatternSchedule {
        PatternSchedule {
            kind: ScheduleKind::Static,
            epochs: 1,
            magnitude_bits: 1.0f64.to_bits(),
        }
    }

    /// Build a schedule, validating the epoch count and magnitude.
    pub fn new(kind: ScheduleKind, epochs: usize, magnitude: f64) -> Result<PatternSchedule> {
        if kind == ScheduleKind::Static {
            // loud, not lossy: `--schedule static --epochs 5` must fail
            // exactly like the label `static:5`, never silently run 1 epoch
            anyhow::ensure!(
                epochs == 1,
                "the static schedule has exactly 1 epoch (got {epochs})"
            );
            anyhow::ensure!(
                magnitude == 1.0,
                "the static schedule has no shift magnitude (got {magnitude})"
            );
            return Ok(PatternSchedule::static_());
        }
        anyhow::ensure!(epochs >= 1, "schedule needs at least 1 epoch");
        anyhow::ensure!(
            magnitude.is_finite() && magnitude > 0.0,
            "schedule magnitude must be a positive finite number, got {magnitude}"
        );
        if kind == ScheduleKind::Churn {
            anyhow::ensure!(
                magnitude <= 1.0,
                "churn magnitude is the fraction of tasks moved per epoch and must be ≤ 1, \
                 got {magnitude}"
            );
        }
        Ok(PatternSchedule {
            kind,
            epochs,
            magnitude_bits: magnitude.to_bits(),
        })
    }

    /// Parse a schedule label: `kind[:epochs[:magnitude]]`, e.g. `static`,
    /// `step`, `step:3`, `step:3:1.5`. Omitted fields take per-kind
    /// defaults.
    pub fn parse(label: &str) -> Result<PatternSchedule> {
        let mut parts = label.split(':');
        let kind_s = parts.next().unwrap_or("").trim();
        let kind = ScheduleKind::parse(kind_s)
            .with_context(|| format!("unknown schedule kind '{kind_s}' in '{label}'"))?;
        let epochs = match parts.next() {
            Some(e) => e
                .trim()
                .parse::<usize>()
                .with_context(|| format!("bad epoch count in schedule '{label}'"))?,
            None => kind.default_epochs(),
        };
        let magnitude = match parts.next() {
            Some(m) => m
                .trim()
                .parse::<f64>()
                .with_context(|| format!("bad magnitude in schedule '{label}'"))?,
            None => kind.default_magnitude(),
        };
        anyhow::ensure!(
            parts.next().is_none(),
            "schedule '{label}' has trailing fields (expected kind[:epochs[:magnitude]])"
        );
        PatternSchedule::new(kind, epochs, magnitude)
            .with_context(|| format!("bad schedule '{label}'"))
    }

    /// Canonical label (round-trips through [`PatternSchedule::parse`]):
    /// `static`, or `kind:epochs:magnitude` with the shortest
    /// round-tripping decimal for the magnitude.
    pub fn label(&self) -> String {
        if self.is_static() {
            "static".to_string()
        } else {
            format!("{}:{}:{}", self.kind.name(), self.epochs, self.magnitude())
        }
    }

    pub fn is_static(&self) -> bool {
        self.kind == ScheduleKind::Static
    }

    pub fn epochs(&self) -> usize {
        self.epochs
    }

    pub fn magnitude(&self) -> f64 {
        f64::from_bits(self.magnitude_bits)
    }

    /// Override the epoch count (CLI `--epochs`).
    pub fn with_epochs(self, epochs: usize) -> Result<PatternSchedule> {
        PatternSchedule::new(self.kind, epochs, self.magnitude())
    }

    /// Override the magnitude (CLI `--magnitude`).
    pub fn with_magnitude(self, magnitude: f64) -> Result<PatternSchedule> {
        PatternSchedule::new(self.kind, self.epochs, magnitude)
    }

    /// Rate multiplier of epoch `e` relative to the *base* pattern (1.0
    /// for `Static`/`Churn` — churn moves demand instead of scaling it).
    pub fn rate_factor(&self, epoch: usize) -> f64 {
        let m = self.magnitude();
        match self.kind {
            ScheduleKind::Static | ScheduleKind::Churn => 1.0,
            ScheduleKind::Step => {
                if epoch == 0 {
                    1.0
                } else {
                    m
                }
            }
            ScheduleKind::Bursty => {
                if epoch % 2 == 1 {
                    m
                } else {
                    1.0
                }
            }
            ScheduleKind::Diurnal => {
                let phase = std::f64::consts::TAU * epoch as f64 / self.epochs as f64;
                1.0 + (m - 1.0) * 0.5 * (1.0 - phase.cos())
            }
            ScheduleKind::Rescale => m.powi(epoch as i32),
        }
    }

    /// The epoch-`e` network: a pure function of `(base, seed, epoch)` —
    /// never of the path taken to reach the epoch — so dynamic sweep
    /// cells stay bit-deterministic across workers and shards. Epoch 0 —
    /// and any epoch whose pattern coincides with the base, like a bursty
    /// off-epoch — is the unmodified base, bit for bit. Only *mutated*
    /// epochs pass through [`ensure_feasible`] (capacity tracks demand,
    /// mirroring the §V feasibility guards of the scenario builders);
    /// running the guard on an untouched epoch would put "base pattern"
    /// epochs on a different cost surface than epoch 0 whenever the base
    /// is tight (e.g. under `--scale`).
    pub fn network_at(&self, base: &Network, seed: u64, epoch: usize) -> Network {
        let mut net = base.clone();
        if epoch == 0 || self.is_static() {
            return net;
        }
        if self.kind == ScheduleKind::Churn {
            // churn accumulates: epoch e folds rounds 1..=e over the base
            for round in 1..=epoch {
                churn_round(&mut net, seed, round as u64, self.magnitude());
            }
        } else {
            let f = self.rate_factor(epoch);
            if f == 1.0 {
                return net;
            }
            net.scale_rates(f);
        }
        ensure_feasible(&mut net);
        net
    }
}

/// One churn round: move a `frac` fraction of the tasks (at least one) —
/// fresh random destination, sources relocated to fresh distinct nodes
/// carrying the *same* rate values (total demand preserved). All draws
/// come from a stream keyed by `(seed, round)`, so the round is
/// reproducible in isolation.
fn churn_round(net: &mut Network, seed: u64, round: u64, frac: f64) {
    let mut rng = Pcg::with_stream(seed ^ 0xd15c_0d15, 0x1157 + round);
    let s = net.s();
    let n = net.n();
    let k = ((s as f64 * frac).ceil() as usize).clamp(1, s);
    for &t in &rng.choose_distinct(s, k) {
        net.tasks[t].dest = rng.below(n);
        let vals: Vec<f64> = net.input_rate[t]
            .iter()
            .copied()
            .filter(|&r| r > 0.0)
            .collect();
        if vals.is_empty() {
            continue;
        }
        let targets = rng.choose_distinct(n, vals.len().min(n));
        for r in net.input_rate[t].iter_mut() {
            *r = 0.0;
        }
        for (v, &i) in vals.into_iter().zip(&targets) {
            net.input_rate[t][i] = v;
        }
    }
}

/// Deterministic feasibility guard for mutated epochs, mirroring the two
/// §V guards of the scenario builders ("we simulate on the scenarios
/// where such pure-local computation is feasible"): queue computation
/// capacities are bumped wherever the shifted local load saturates them,
/// and queue link capacities are inflated geometrically until the
/// all-local strategy has finite cost. Unlike the builders this draws no
/// randomness — the guard is a pure function of the network, which the
/// per-cell determinism contract requires.
pub fn ensure_feasible(net: &mut Network) {
    for i in 0..net.n() {
        let mut load = 0.0;
        for (s, task) in net.tasks.iter().enumerate() {
            load += net.comp_weight[i][task.ctype] * net.input_rate[s][i];
        }
        if let CostFn::Queue { cap } = &mut net.comp_cost[i] {
            if *cap <= 1.25 * load {
                *cap = 1.5 * 1.25 * load + 1e-6;
            }
        }
    }
    for _round in 0..40 {
        let phi0 = Strategy::local_compute_init(net);
        let finite = compute_flows(net, &phi0)
            .map(|f| f.total_cost.is_finite())
            .unwrap_or(false);
        if finite {
            return;
        }
        for c in &mut net.link_cost {
            if let CostFn::Queue { cap } = c {
                *cap *= 1.3;
            }
        }
    }
}

/// Per-epoch record of a dynamic run.
#[derive(Clone, Debug)]
pub struct EpochTrace {
    pub epoch: usize,
    /// Cost of the epoch's *starting* strategy on the shifted pattern —
    /// the warm-carried point for warm runs, the all-local point for cold
    /// runs (and for warm runs whose carried point saturated a queue; see
    /// [`EpochTrace::warm_fallback`]).
    pub shift_cost: f64,
    /// Converged cost of the epoch.
    pub final_cost: f64,
    /// Iterations the epoch ran (the re-convergence count after a shift).
    pub iterations: usize,
    /// First iteration within 1% of the epoch's final cost.
    pub iters_to_1pct: usize,
    /// Transient regret vs. the epoch's converged cost:
    /// `Σ_t max(0, T_t − T_final)` over the epoch's trajectory.
    pub transient_regret: f64,
    /// True when a warm start saturated a queue on the new pattern and the
    /// runner fell back to the all-local point (mirrors
    /// [`crate::sim::run_with_failure`]).
    pub warm_fallback: bool,
    /// Strategy-store outcome for this epoch: `Some(true)` when a
    /// verified entry was adopted instead of re-solving, `Some(false)`
    /// for a counted miss, `None` when no external store was consulted
    /// (the default path) — excluded from trace JSON in that case, so
    /// store-less traces stay byte-identical to prior releases.
    pub cache_hit: Option<bool>,
    /// The epoch's converged strategy — shipped only on store-backed runs
    /// (`--cache-dir`), carrying the strategy through the artifact;
    /// `None` (and absent from JSON) otherwise.
    pub phi: Option<Strategy>,
    /// Cost after each iteration of the epoch.
    pub costs: Vec<f64>,
}

impl EpochTrace {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("epoch", Json::Num(self.epoch as f64))
            .set("shift_cost", Json::Num(self.shift_cost))
            .set("final_cost", Json::Num(self.final_cost))
            .set(
                "final_cost_bits",
                Json::Str(format!("{:016x}", self.final_cost.to_bits())),
            )
            .set("iterations", Json::Num(self.iterations as f64))
            .set("iters_to_1pct", Json::Num(self.iters_to_1pct as f64))
            .set("transient_regret", Json::Num(self.transient_regret))
            .set("warm_fallback", Json::Bool(self.warm_fallback))
            .set("costs", Json::from_f64_slice(&self.costs));
        if let Some(hit) = self.cache_hit {
            o.set("cache_hit", Json::Bool(hit));
        }
        if let Some(phi) = &self.phi {
            o.set("strategy", phi.to_json());
        }
        o
    }
}

/// A completed dynamic run: one epoch trace per schedule epoch.
#[derive(Clone, Debug)]
pub struct DynamicTrace {
    pub scenario: String,
    pub seed: u64,
    pub schedule: PatternSchedule,
    /// Algorithm label as reported by the per-epoch runs (`sgp`,
    /// `sgp-native`, `gp`, …).
    pub algorithm: String,
    pub warm: bool,
    pub epochs: Vec<EpochTrace>,
}

impl DynamicTrace {
    /// Total iterations across the epochs *after* the first — the
    /// re-convergence budget the warm-vs-cold comparison cares about.
    pub fn reconvergence_iterations(&self) -> usize {
        self.epochs.iter().skip(1).map(|e| e.iterations).sum()
    }

    pub fn to_json(&self) -> Json {
        let epochs: Vec<Json> = self.epochs.iter().map(EpochTrace::to_json).collect();
        let mut o = Json::obj();
        o.set("scenario", Json::Str(self.scenario.clone()))
            .set("seed", Json::Num(self.seed as f64))
            .set("schedule", Json::Str(self.schedule.label()))
            .set("algorithm", Json::Str(self.algorithm.clone()))
            .set(
                "mode",
                Json::Str(if self.warm { "warm" } else { "cold" }.to_string()),
            )
            .set("epochs", Json::Arr(epochs));
        o
    }
}

/// One epoch's full output of the shared adaptive loop: the mutated
/// network, the (solved or store-adopted) cost trajectory with its
/// converged strategy, and the warm-start bookkeeping the [`EpochTrace`]
/// reports.
struct EpochRun {
    net: Network,
    algorithm: String,
    costs: Vec<f64>,
    iters_to_1pct: usize,
    phi: Strategy,
    shift_cost: f64,
    warm_fallback: bool,
    cache_hit: Option<bool>,
}

impl EpochRun {
    fn final_cost(&self) -> f64 {
        *self.costs.last().expect("epochs run at least one iteration")
    }
}

/// Drives one scenario through a [`PatternSchedule`], re-optimizing every
/// epoch from either the previous epoch's strategy (warm) or the
/// all-local point (cold).
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveRunner {
    /// Iterative algorithm to re-run each epoch: SGP (any backend) or GP
    /// (sparse). See [`Algorithm::supports_dynamic`].
    pub algorithm: Algorithm,
    /// Dense-evaluation route for SGP epochs.
    pub backend: CellBackend,
    /// Warm-start each epoch from the previous strategy
    /// ([`Strategy::retarget`]) instead of the all-local point.
    pub warm: bool,
    pub run: RunConfig,
}

impl AdaptiveRunner {
    /// SGP on the sparse path, warm-started — the paper's adaptivity mode.
    pub fn warm(run: RunConfig) -> AdaptiveRunner {
        AdaptiveRunner {
            algorithm: Algorithm::Sgp,
            backend: CellBackend::Sparse,
            warm: true,
            run,
        }
    }

    /// SGP on the sparse path, cold-started every epoch — the baseline the
    /// adaptivity claim is measured against.
    pub fn cold(run: RunConfig) -> AdaptiveRunner {
        AdaptiveRunner {
            warm: false,
            ..AdaptiveRunner::warm(run)
        }
    }

    /// Run a named scenario (see [`super::build_scenario_network`])
    /// through `schedule`.
    pub fn run_scenario(
        &self,
        scenario: &str,
        seed: u64,
        rate_scale: f64,
        schedule: PatternSchedule,
    ) -> Result<DynamicTrace> {
        let base = build_scenario_network(scenario, seed, rate_scale)?;
        self.run_network(scenario, &base, seed, schedule)
    }

    /// [`AdaptiveRunner::run_scenario`] riding an external strategy store
    /// (the `cecflow dynamic --cache-dir` path): each epoch consults the
    /// store before solving — a verified entry is adopted wholesale — and
    /// the per-epoch converged strategies ship in the trace
    /// ([`EpochTrace::phi`]).
    pub fn run_scenario_with_store(
        &self,
        scenario: &str,
        seed: u64,
        rate_scale: f64,
        schedule: PatternSchedule,
        store: &dyn StrategyStore,
    ) -> Result<DynamicTrace> {
        let base = build_scenario_network(scenario, seed, rate_scale)?;
        self.run_network_with_store(scenario, &base, seed, schedule, Some(store))
    }

    /// Run an already-built base network through `schedule`. `seed` keys
    /// the churn draws (scaling kinds are deterministic without it).
    pub fn run_network(
        &self,
        name: &str,
        base: &Network,
        seed: u64,
        schedule: PatternSchedule,
    ) -> Result<DynamicTrace> {
        self.run_network_with_store(name, base, seed, schedule, None)
    }

    /// [`AdaptiveRunner::run_network`] with an optional external strategy
    /// store. `store = None` is bit-for-bit `run_network`, and its trace
    /// JSON is byte-identical to prior releases (no `cache_hit`, no
    /// shipped strategies).
    pub fn run_network_with_store(
        &self,
        name: &str,
        base: &Network,
        seed: u64,
        schedule: PatternSchedule,
        store: Option<&dyn StrategyStore>,
    ) -> Result<DynamicTrace> {
        let runs = self.run_epochs(name, base, seed, &schedule, store)?;
        let algorithm = runs
            .last()
            .map(|r| r.algorithm.clone())
            .unwrap_or_else(|| self.algorithm.name().to_string());
        let epochs = runs
            .into_iter()
            .enumerate()
            .map(|(e, run)| {
                let final_cost = run.final_cost();
                EpochTrace {
                    epoch: e,
                    shift_cost: run.shift_cost,
                    final_cost,
                    iterations: run.costs.len(),
                    iters_to_1pct: run.iters_to_1pct,
                    transient_regret: metrics::transient_regret(&run.costs, final_cost),
                    warm_fallback: run.warm_fallback,
                    cache_hit: run.cache_hit,
                    phi: run.cache_hit.is_some().then_some(run.phi),
                    costs: run.costs,
                }
            })
            .collect();
        Ok(DynamicTrace {
            scenario: name.to_string(),
            seed,
            schedule,
            algorithm,
            warm: self.warm,
            epochs,
        })
    }

    /// Per-epoch converged `(mutated network, strategy)` snapshots — the
    /// input the request-level simulator ([`crate::sim::tasks`]) walks.
    /// Same warm-start/fallback path as [`AdaptiveRunner::run_network`];
    /// only the retained outputs differ.
    pub fn converged_epochs(
        &self,
        name: &str,
        base: &Network,
        seed: u64,
        schedule: &PatternSchedule,
    ) -> Result<Vec<(Network, Strategy)>> {
        Ok(self
            .run_epochs(name, base, seed, schedule, None)?
            .into_iter()
            .map(|run| (run.net, run.phi))
            .collect())
    }

    /// Store key of one epoch of this runner's trace: the pre-solve
    /// identity `(scenario name, seed, algorithm, backend, schedule
    /// label, start mode, stopping rule, epoch)` folded into the salted
    /// store hasher (`store::key_hasher`). The base network itself is
    /// deliberately not folded in ([`AdaptiveRunner::run_network`]
    /// accepts a prebuilt base, e.g. under `--scale`): a key collision
    /// across bases is caught by re-pricing verification and degrades to
    /// a counted miss, never a wrong adoption.
    fn epoch_store_key(
        &self,
        name: &str,
        seed: u64,
        schedule: &PatternSchedule,
        epoch: usize,
    ) -> u64 {
        let mut h = store::key_hasher();
        h.eat(b"dynamic-epoch");
        h.eat(&[0]);
        h.eat(name.as_bytes());
        h.eat(&[0]);
        h.eat(&seed.to_le_bytes());
        h.eat(self.algorithm.name().as_bytes());
        h.eat(&[0]);
        h.eat(self.backend.name().as_bytes());
        h.eat(&[0]);
        h.eat(schedule.label().as_bytes());
        h.eat(&[0]);
        h.eat(&[self.warm as u8]);
        h.eat(&(self.run.max_iters as u64).to_le_bytes());
        h.eat(&self.run.tol.to_bits().to_le_bytes());
        h.eat(&(self.run.patience as u64).to_le_bytes());
        h.eat(&(epoch as u64).to_le_bytes());
        h.finish()
    }

    /// The shared epoch loop: mutate, warm-start (with infeasible-warm
    /// fallback to all-local), re-optimize, carry the strategy forward.
    ///
    /// The epoch-to-epoch carry rides a [`StrategyStore`]: every solved
    /// epoch is saved under `epoch_store_key` and the next epoch's warm
    /// start loads it back. Without an external store the carrier is a
    /// private [`MemStore`], reproducing the old `runs.last()` warm path
    /// bit for bit (entries round-trip bits-exact). With one
    /// (`--cache-dir`), each epoch additionally *consults* the store
    /// before solving: a verified entry for the epoch itself is adopted
    /// wholesale — its stored trajectory is reported and the solve is
    /// skipped — while the starting strategy, shift cost and fallback
    /// bookkeeping are recomputed exactly as in a solving run, so the
    /// trace keeps fingerprint equality with the store-less run.
    fn run_epochs(
        &self,
        name: &str,
        base: &Network,
        seed: u64,
        schedule: &PatternSchedule,
        external: Option<&dyn StrategyStore>,
    ) -> Result<Vec<EpochRun>> {
        let carrier = MemStore::new();
        let store: &dyn StrategyStore = external.unwrap_or(&carrier);
        // One optimizer workspace for the whole trace: epochs reuse the
        // arena (reshaped automatically when churn changes the edge
        // count), so steady-state epochs re-optimize allocation-free.
        let mut ws = OptWorkspace::new();
        let mut runs: Vec<EpochRun> = Vec::with_capacity(schedule.epochs());
        for e in 0..schedule.epochs() {
            let net = schedule.network_at(base, seed, e);
            let mut warm_fallback = false;
            let mut phi0 = match runs.last() {
                Some(prev) if self.warm => {
                    // the carried point comes from the store (saved by the
                    // previous loop turn — identical bits to `prev.phi`);
                    // a foreign, stale or unsaved entry falls back to the
                    // in-hand strategy
                    let carried = store
                        .load(self.epoch_store_key(name, seed, schedule, e - 1))
                        .filter(|entry| entry.verifies_on(&prev.net))
                        .map(|entry| entry.phi)
                        .unwrap_or_else(|| prev.phi.clone());
                    carried.retarget(&prev.net, &net)
                }
                _ => Strategy::local_compute_init(&net),
            };
            let mut shift_cost = compute_flows(&net, &phi0)
                .with_context(|| format!("pricing the epoch-{e} starting strategy"))?
                .total_cost;
            if !shift_cost.is_finite() {
                // The carried point can saturate a queue after the shift;
                // fall back to the always-safe all-local strategy (the
                // feasibility guard keeps it finite on every epoch).
                let cold = Strategy::local_compute_init(&net);
                let cold_cost = compute_flows(&net, &cold)?.total_cost;
                anyhow::ensure!(
                    cold_cost.is_finite(),
                    "epoch {e} of schedule {} on {name} (seed {seed}) is infeasible even \
                     under all-local computation",
                    schedule.label()
                );
                phi0 = cold;
                shift_cost = cold_cost;
                warm_fallback = true;
            }
            // Only an external store is consulted for the epoch itself —
            // the private carrier cannot hold epoch `e` before it runs.
            let key = self.epoch_store_key(name, seed, schedule, e);
            let mut cache_hit = external.map(|_| false);
            let mut adopted: Option<StoredRun> = None;
            if external.is_some() {
                match store.load(key) {
                    Some(entry) if entry.verifies_on(&net) => {
                        cache_hit = Some(true);
                        adopted = Some(entry);
                    }
                    Some(_) => eprintln!(
                        "warning: strategy store: entry {key:016x} failed re-pricing \
                         verification; re-running epoch {e} cold"
                    ),
                    None => {}
                }
            }
            let (algorithm, costs, iters_to_1pct, phi) = match adopted {
                Some(entry) => {
                    let costs = entry.costs();
                    (entry.algorithm, costs, entry.iters_to_1pct, entry.phi)
                }
                None => {
                    let out = self.optimize_epoch(&net, &phi0, &mut ws).with_context(|| {
                        format!("optimizing epoch {e} of schedule {}", schedule.label())
                    })?;
                    let iters_to_1pct = metrics::iters_to_1pct(&out.costs);
                    let phi = out
                        .phi
                        .context("iterative dynamic optimizer returned no strategy")?;
                    // best-effort save, sealed with the re-priced cost so
                    // a later consult can verify; a saturated run is not
                    // worth warming from and is skipped
                    match compute_flows(&net, &phi) {
                        Ok(f) if f.total_cost.is_finite() => store.save(
                            key,
                            &StoredRun::capture(
                                &out.algorithm,
                                &out.costs,
                                iters_to_1pct,
                                f.total_cost,
                                &phi,
                            ),
                        ),
                        _ => {}
                    }
                    (out.algorithm, out.costs, iters_to_1pct, phi)
                }
            };
            runs.push(EpochRun {
                net,
                algorithm,
                costs,
                iters_to_1pct,
                phi,
                shift_cost,
                warm_fallback,
                cache_hit,
            });
        }
        Ok(runs)
    }

    /// One epoch's optimization from an explicit starting strategy,
    /// routed through the shared warm entry point
    /// ([`super::run_algorithm_with_backend_warm`]) — the same
    /// sparse / native / pjrt plumbing the sweep cells use. A fresh
    /// optimizer per epoch keeps epochs independent (and matches the
    /// Fig. 5b failure driver); the *strategy* is what carries across
    /// epochs.
    fn optimize_epoch(
        &self,
        net: &Network,
        phi0: &Strategy,
        ws: &mut OptWorkspace,
    ) -> Result<AlgoOutcome> {
        match (self.algorithm, self.backend) {
            (Algorithm::Sgp, _) | (Algorithm::Gp, CellBackend::Sparse) => {}
            (algo, backend) => bail!(
                "the dynamic engine re-optimizes sgp (any backend) and gp (sparse); got {} \
                 on the {} backend",
                algo.name(),
                backend.name()
            ),
        }
        super::run_algorithm_with_backend_warm_ws(
            net,
            self.algorithm,
            self.backend,
            &self.run,
            Some(phi0),
            ws,
        )
    }
}

/// One cell of the `cecflow dynamic` grid: a start mode (warm or cold)
/// of the same `(scenario, seed, schedule)` instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DynamicCell {
    /// Warm-start this trace from the previous epoch's strategy.
    pub warm: bool,
}

impl GridCell for DynamicCell {
    fn describe(&self, index: usize) -> String {
        format!(
            "dynamic cell {index} ({} start)",
            if self.warm { "warm" } else { "cold" }
        )
    }

    fn write_identity(&self, h: &mut GridHasher) {
        h.eat(&[self.warm as u8]);
    }
}

/// The `cecflow dynamic` grid *definition*: one [`DynamicCell`] per
/// requested start mode of a single `(scenario, seed, schedule)`
/// instance, routed through the execution engine's worker pool
/// ([`super::exec::pool`]) so the warm and cold traces price
/// concurrently. This is the same engine the sweep runs on — the dynamic
/// subcommand is just a two-cell grid.
#[derive(Clone, Debug)]
pub struct DynamicSpec {
    pub scenario: String,
    pub seed: u64,
    pub rate_scale: f64,
    pub algorithm: Algorithm,
    pub backend: CellBackend,
    pub schedule: PatternSchedule,
    pub run: RunConfig,
    /// Start modes to trace, in output order (`true` = warm).
    pub modes: Vec<bool>,
    /// Strategy-store directory (CLI `--cache-dir`): when set, every mode
    /// cell consults/feeds an [`FsStore`] there and its trace ships the
    /// per-epoch converged strategies. `None` keeps the output
    /// byte-identical to a store-less build.
    pub cache: Option<String>,
}

impl DynamicSpec {
    /// The mode cells wrapped for the execution engine.
    pub fn grid(&self) -> Grid<DynamicCell> {
        Grid::new(self.modes.iter().map(|&warm| DynamicCell { warm }).collect())
    }

    /// Run every mode cell on up to `workers` pool threads and return the
    /// traces in mode order. Each cell is an independent
    /// [`AdaptiveRunner::run_scenario`] — results are bit-identical to
    /// running the modes sequentially.
    pub fn run(&self, workers: usize) -> Result<Vec<DynamicTrace>> {
        let grid = self.grid();
        anyhow::ensure!(
            !grid.is_empty(),
            "dynamic run needs at least one start mode (warm or cold)"
        );
        let fs = match &self.cache {
            Some(dir) => {
                anyhow::ensure!(!dir.is_empty(), "--cache-dir needs a non-empty directory path");
                Some(FsStore::open(Path::new(dir))?)
            }
            None => None,
        };
        let cells = grid.indexed();
        pool::run_cells(
            &cells,
            workers,
            |_, cell| {
                let runner = AdaptiveRunner {
                    algorithm: self.algorithm,
                    backend: self.backend,
                    warm: cell.warm,
                    run: self.run,
                };
                match &fs {
                    Some(s) => runner.run_scenario_with_store(
                        &self.scenario,
                        self.seed,
                        self.rate_scale,
                        self.schedule,
                        s,
                    ),
                    None => runner.run_scenario(
                        &self.scenario,
                        self.seed,
                        self.rate_scale,
                        self.schedule,
                    ),
                }
            },
            None,
        )
    }
}

/// Parse a comma-separated schedule list (`"static,step:3:1.5"`).
pub fn parse_schedules(s: &str) -> Result<Vec<PatternSchedule>> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(PatternSchedule::parse)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_labels_roundtrip() {
        for label in [
            "static",
            "step:3:1.5",
            "bursty:4:2",
            "diurnal:6:2",
            "churn:3:0.25",
            "rescale:3:1.25",
        ] {
            let s = PatternSchedule::parse(label).unwrap();
            let back = PatternSchedule::parse(&s.label()).unwrap();
            assert_eq!(s, back, "{label}");
        }
        // defaults fill in
        let s = PatternSchedule::parse("step").unwrap();
        assert_eq!(s.kind, ScheduleKind::Step);
        assert_eq!(s.epochs(), 3);
        assert_eq!(s.magnitude(), 1.5);
        assert_eq!(PatternSchedule::parse("static").unwrap(), PatternSchedule::static_());
        // rejections
        assert!(PatternSchedule::parse("zzz").is_err());
        assert!(PatternSchedule::parse("step:0").is_err());
        assert!(PatternSchedule::parse("step:3:-1").is_err());
        assert!(PatternSchedule::parse("churn:3:2").is_err());
        assert!(PatternSchedule::parse("step:3:1.5:x").is_err());
        // static rejects overrides loudly on every input path — the CLI's
        // `--schedule static --epochs 5` must not silently run 1 epoch
        assert!(PatternSchedule::parse("static:5").is_err());
        assert!(PatternSchedule::parse("static:1:2").is_err());
        assert!(PatternSchedule::static_().with_epochs(5).is_err());
        assert!(PatternSchedule::static_().with_magnitude(2.0).is_err());
        assert!(PatternSchedule::static_().with_epochs(1).is_ok());
    }

    #[test]
    fn rate_factors_match_the_kind() {
        let step = PatternSchedule::parse("step:3:1.5").unwrap();
        assert_eq!(step.rate_factor(0), 1.0);
        assert_eq!(step.rate_factor(1), 1.5);
        assert_eq!(step.rate_factor(2), 1.5);
        let bursty = PatternSchedule::parse("bursty:4:2").unwrap();
        assert_eq!(bursty.rate_factor(0), 1.0);
        assert_eq!(bursty.rate_factor(1), 2.0);
        assert_eq!(bursty.rate_factor(2), 1.0);
        let rescale = PatternSchedule::parse("rescale:3:1.25").unwrap();
        assert_eq!(rescale.rate_factor(0), 1.0);
        assert_eq!(rescale.rate_factor(2), 1.25 * 1.25);
        let diurnal = PatternSchedule::parse("diurnal:4:2").unwrap();
        assert_eq!(diurnal.rate_factor(0), 1.0);
        // the mid-schedule peak hits the full magnitude
        assert!((diurnal.rate_factor(2) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn network_at_is_pure_and_epoch0_is_the_base() {
        let base = super::super::build_scenario_network("abilene", 7, 1.0).unwrap();
        for label in ["step:3:1.5", "bursty:4:2", "churn:3:0.25", "rescale:3:1.25"] {
            let s = PatternSchedule::parse(label).unwrap();
            for e in 0..s.epochs() {
                let a = s.network_at(&base, 7, e);
                let b = s.network_at(&base, 7, e);
                assert_eq!(a.tasks, b.tasks, "{label} epoch {e}");
                assert_eq!(a.input_rate, b.input_rate, "{label} epoch {e}");
                assert!(a.validate().is_empty(), "{label} epoch {e}: {:?}", a.validate());
                assert!(a.local_computation_feasible(), "{label} epoch {e}");
                let phi0 = Strategy::local_compute_init(&a);
                assert!(
                    compute_flows(&a, &phi0).unwrap().total_cost.is_finite(),
                    "{label} epoch {e}: infinite all-local cost"
                );
            }
            let e0 = s.network_at(&base, 7, 0);
            assert_eq!(e0.input_rate, base.input_rate, "{label}: epoch 0 mutated");
            assert_eq!(e0.tasks, base.tasks, "{label}: epoch 0 mutated");
        }
    }

    #[test]
    fn unmutated_epochs_are_the_base_bit_for_bit() {
        // A bursty off-epoch (rate factor 1.0) must be the *raw* base —
        // including its cost parameters. Running the feasibility guard on
        // it would silently repair a tight base and put "base pattern"
        // epochs on a different cost surface than epoch 0.
        let mut base = super::super::build_scenario_network("abilene", 7, 1.0).unwrap();
        // tighten one queue so the guard *would* fire if (wrongly) applied
        if let CostFn::Queue { cap } = &mut base.comp_cost[0] {
            *cap *= 0.5;
        }
        let s = PatternSchedule::parse("bursty:4:2").unwrap();
        let off = s.network_at(&base, 7, 2);
        assert_eq!(off.input_rate, base.input_rate);
        assert_eq!(off.comp_cost, base.comp_cost, "off-epoch cost params mutated");
        assert_eq!(off.link_cost, base.link_cost, "off-epoch cost params mutated");
    }

    #[test]
    fn step_epochs_after_the_shift_are_identical() {
        let base = super::super::build_scenario_network("abilene", 3, 1.0).unwrap();
        let s = PatternSchedule::parse("step:4:1.5").unwrap();
        let e1 = s.network_at(&base, 3, 1);
        let e3 = s.network_at(&base, 3, 3);
        assert_eq!(e1.input_rate, e3.input_rate);
        assert_eq!(e1.tasks, e3.tasks);
    }

    #[test]
    fn churn_moves_demand_without_changing_the_total() {
        let base = super::super::build_scenario_network("connected-er", 5, 1.0).unwrap();
        let s = PatternSchedule::parse("churn:3:0.25").unwrap();
        let e2 = s.network_at(&base, 5, 2);
        assert_eq!(e2.s(), base.s());
        let total =
            |n: &Network| -> f64 { (0..n.s()).map(|t| n.task_input(t)).sum::<f64>() };
        assert!(
            (total(&e2) - total(&base)).abs() < 1e-9,
            "churn changed total demand: {} vs {}",
            total(&e2),
            total(&base)
        );
        assert_ne!(
            (e2.tasks.clone(), e2.input_rate.clone()),
            (base.tasks.clone(), base.input_rate.clone()),
            "churn changed nothing"
        );
    }

    #[test]
    fn warm_and_cold_share_epoch0_and_stay_finite() {
        let cfg = RunConfig::quick();
        let s = PatternSchedule::parse("step:3:1.5").unwrap();
        let warm = AdaptiveRunner::warm(cfg)
            .run_scenario("abilene", 1, 1.0, s)
            .unwrap();
        let cold = AdaptiveRunner::cold(cfg)
            .run_scenario("abilene", 1, 1.0, s)
            .unwrap();
        assert_eq!(warm.epochs.len(), 3);
        assert_eq!(cold.epochs.len(), 3);
        assert_eq!(
            warm.epochs[0].final_cost.to_bits(),
            cold.epochs[0].final_cost.to_bits(),
            "epoch 0 has no history — warm and cold must coincide"
        );
        for t in warm.epochs.iter().chain(&cold.epochs) {
            assert!(t.final_cost.is_finite(), "epoch {} diverged", t.epoch);
            assert!(t.final_cost <= t.shift_cost + 1e-9, "epoch {} ascended", t.epoch);
            assert!(t.transient_regret >= 0.0);
            assert!(t.iters_to_1pct >= 1 && t.iters_to_1pct <= t.iterations);
        }
    }

    #[test]
    fn dynamic_engine_rejects_non_iterative_algorithms() {
        let runner = AdaptiveRunner {
            algorithm: Algorithm::Lpr,
            ..AdaptiveRunner::warm(RunConfig::quick())
        };
        let err = runner
            .run_scenario("abilene", 1, 1.0, PatternSchedule::parse("step").unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("sgp"), "{err}");
    }

    #[test]
    fn trace_json_has_the_documented_shape() {
        let cfg = RunConfig::quick();
        let trace = AdaptiveRunner::warm(cfg)
            .run_scenario("abilene", 1, 1.0, PatternSchedule::parse("step:2:1.5").unwrap())
            .unwrap();
        let doc = trace.to_json();
        assert_eq!(doc.get("schedule").as_str(), Some("step:2:1.5"));
        assert_eq!(doc.get("mode").as_str(), Some("warm"));
        let epochs = doc.get("epochs").as_arr().unwrap();
        assert_eq!(epochs.len(), 2);
        assert!(epochs[0].get("final_cost_bits").as_str().is_some());
        assert!(epochs[0].get("costs").as_arr().is_some());
        // and it survives a parse round-trip
        let back = Json::parse(&doc.pretty()).unwrap();
        assert_eq!(back.get("epochs").as_arr().unwrap().len(), 2);
    }

    #[test]
    fn dynamic_spec_routes_modes_through_the_pool_bit_identically() {
        let cfg = RunConfig::quick();
        let schedule = PatternSchedule::parse("step:2:1.5").unwrap();
        let spec = DynamicSpec {
            scenario: "abilene".into(),
            seed: 1,
            rate_scale: 1.0,
            algorithm: Algorithm::Sgp,
            backend: CellBackend::Sparse,
            schedule,
            run: cfg,
            modes: vec![true, false],
            cache: None,
        };
        let traces = spec.run(2).unwrap();
        assert_eq!(traces.len(), 2);
        assert!(traces[0].warm && !traces[1].warm, "mode order must hold");
        let direct_warm = AdaptiveRunner::warm(cfg)
            .run_scenario("abilene", 1, 1.0, schedule)
            .unwrap();
        let direct_cold = AdaptiveRunner::cold(cfg)
            .run_scenario("abilene", 1, 1.0, schedule)
            .unwrap();
        for (engine, direct) in [(&traces[0], &direct_warm), (&traces[1], &direct_cold)] {
            let bits = |t: &DynamicTrace| -> Vec<u64> {
                t.epochs.iter().map(|e| e.final_cost.to_bits()).collect()
            };
            assert_eq!(bits(engine), bits(direct), "engine-routed trace drifted");
        }
        // an empty mode list is a loud error, not a silent no-op
        let empty = DynamicSpec {
            modes: vec![],
            ..spec
        };
        assert!(empty.run(1).is_err());
    }

    #[test]
    fn store_backed_rerun_adopts_epochs_bit_for_bit() {
        let cfg = RunConfig::quick();
        let s = PatternSchedule::parse("step:3:1.5").unwrap();
        let runner = AdaptiveRunner::warm(cfg);
        let bits = |t: &DynamicTrace| -> Vec<u64> {
            t.epochs.iter().map(|e| e.final_cost.to_bits()).collect()
        };
        let plain = runner.run_scenario("abilene", 1, 1.0, s).unwrap();
        assert!(plain
            .epochs
            .iter()
            .all(|e| e.cache_hit.is_none() && e.phi.is_none()));
        let doc = plain.to_json();
        let e0 = &doc.get("epochs").as_arr().unwrap()[0];
        assert!(
            e0.get("strategy").as_obj().is_none(),
            "store-less trace shipped a strategy"
        );
        assert!(e0.get("cache_hit").as_bool().is_none());

        // first store-backed run: all misses, same bits, store populated
        let store = MemStore::new();
        let first = runner
            .run_scenario_with_store("abilene", 1, 1.0, s, &store)
            .unwrap();
        assert_eq!(bits(&first), bits(&plain), "store participation changed the trace");
        assert!(first.epochs.iter().all(|e| e.cache_hit == Some(false)));
        assert!(first.epochs.iter().all(|e| e.phi.is_some()));
        assert_eq!(store.len(), 3);

        // second run: every epoch adopted, full per-epoch bit equality
        let second = runner
            .run_scenario_with_store("abilene", 1, 1.0, s, &store)
            .unwrap();
        assert!(second.epochs.iter().all(|e| e.cache_hit == Some(true)));
        for (a, b) in plain.epochs.iter().zip(&second.epochs) {
            assert_eq!(a.shift_cost.to_bits(), b.shift_cost.to_bits(), "epoch {}", a.epoch);
            assert_eq!(a.iterations, b.iterations, "epoch {}", a.epoch);
            assert_eq!(a.iters_to_1pct, b.iters_to_1pct, "epoch {}", a.epoch);
            assert_eq!(a.warm_fallback, b.warm_fallback, "epoch {}", a.epoch);
            let ca: Vec<u64> = a.costs.iter().map(|c| c.to_bits()).collect();
            let cb: Vec<u64> = b.costs.iter().map(|c| c.to_bits()).collect();
            assert_eq!(ca, cb, "epoch {}", a.epoch);
        }
        let sdoc = second.to_json();
        let se0 = &sdoc.get("epochs").as_arr().unwrap()[0];
        assert!(se0.get("strategy").as_obj().is_some(), "store run must ship strategies");
        assert_eq!(se0.get("cache_hit").as_bool(), Some(true));
    }

    #[test]
    fn parse_schedule_lists() {
        let xs = parse_schedules("static, step:3:1.5").unwrap();
        assert_eq!(xs.len(), 2);
        assert!(xs[0].is_static());
        assert_eq!(xs[1].label(), "step:3:1.5");
        assert!(parse_schedules("static,zzz").is_err());
    }
}
