//! Table II scenario builders: paper-faithful random network instances.
//!
//! Parameter recipe (§V):
//! * `M = 5` computation types; `a_m ~ Exp(0.5)` truncated to `[0.1, 5]`;
//! * each task gets a uniform random type and destination plus `|R|`
//!   random active data sources with rates `U[r_min, r_max]`,
//!   `[0.5, 1.5]`;
//! * link costs: Linear with unit `d_ij ~ U[0, 2·d̄]`, or Queue with
//!   capacity `d_ij ~ U[0, 2·d̄]`;
//! * computation costs: Linear (`s_i` uniform with mean `s̄`) or Queue
//!   (`s_i ~ Exp(s̄)`), weights `w_im ~ U[1, 5]`.
//!
//! Two guards keep instances *feasible* where the paper implicitly assumes
//! it ("we simulate on the scenarios where such pure-local computation is
//! feasible", §V): computation capacities are redrawn/bumped until every
//! node can absorb its local input, and link capacities are inflated
//! geometrically until the all-local initial strategy has finite cost.
//! Both adjustments preserve the congestion regime and are documented in
//! DESIGN.md §3.6.

use crate::graph::topology::{connected_er, TopologyKind};
use crate::model::cost::CostFn;
use crate::model::network::{Network, Task};
use crate::model::strategy::Strategy;
use crate::util::rng::Pcg;

/// Cost-family selector for a scenario.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CostKind {
    Linear,
    Queue,
}

/// A scenario specification (one Table II row).
#[derive(Clone, Debug)]
pub struct ScenarioSpec {
    pub name: &'static str,
    pub topology: TopologyKind,
    /// `|S|` tasks.
    pub num_tasks: usize,
    /// `|R|` active data sources per task.
    pub sources_per_task: usize,
    pub link_kind: CostKind,
    /// `d̄_ij` mean link parameter.
    pub link_mean: f64,
    pub comp_kind: CostKind,
    /// `s̄_i` mean computation parameter.
    pub comp_mean: f64,
    /// `M` computation types.
    pub num_types: usize,
    pub r_min: f64,
    pub r_max: f64,
}

impl ScenarioSpec {
    /// The seven Table II rows. `SW` defaults to the Queue variant; see
    /// [`ScenarioSpec::sw_linear`] for the `SW-linear` column of Fig. 4.
    pub fn table2() -> Vec<ScenarioSpec> {
        use TopologyKind::*;
        let mk = |name, topology, num_tasks, sources, link_mean, comp_mean| ScenarioSpec {
            name,
            topology,
            num_tasks,
            sources_per_task: sources,
            link_kind: CostKind::Queue,
            link_mean,
            comp_kind: CostKind::Queue,
            comp_mean,
            num_types: 5,
            r_min: 0.5,
            r_max: 1.5,
        };
        vec![
            mk("connected-er", ConnectedEr, 15, 5, 10.0, 12.0),
            mk("balanced-tree", BalancedTree, 20, 5, 20.0, 15.0),
            mk("fog", Fog, 30, 5, 20.0, 17.0),
            mk("abilene", Abilene, 10, 3, 15.0, 10.0),
            mk("lhc", Lhc, 30, 5, 15.0, 15.0),
            mk("geant", Geant, 40, 7, 20.0, 20.0),
            mk("sw", SmallWorld, 120, 10, 20.0, 20.0),
        ]
    }

    /// Beyond-Table-II rows over the extended topology library (ISSUE 4):
    /// a 5×4 torus grid, a Barabási–Albert scale-free graph and a k=4
    /// fat-tree, at Table-II-like task densities — the diverse substrate
    /// the dynamic task-pattern schedules run over.
    pub fn extended() -> Vec<ScenarioSpec> {
        use TopologyKind::*;
        let mk = |name, topology, num_tasks, sources, link_mean, comp_mean| ScenarioSpec {
            name,
            topology,
            num_tasks,
            sources_per_task: sources,
            link_kind: CostKind::Queue,
            link_mean,
            comp_kind: CostKind::Queue,
            comp_mean,
            num_types: 5,
            r_min: 0.5,
            r_max: 1.5,
        };
        vec![
            mk("grid-torus", Torus, 20, 5, 15.0, 14.0),
            mk("scale-free", ScaleFree, 25, 5, 15.0, 15.0),
            mk("fat-tree", FatTree, 20, 5, 20.0, 15.0),
        ]
    }

    /// The full scenario library: the seven Table II rows plus the
    /// extended-topology rows.
    pub fn all() -> Vec<ScenarioSpec> {
        let mut specs = ScenarioSpec::table2();
        specs.extend(ScenarioSpec::extended());
        specs
    }

    /// Find one scenario row by name — Table II, the "sw-linear" /
    /// "sw-queue" variants, and the extended-topology rows.
    pub fn by_name(name: &str) -> Option<ScenarioSpec> {
        if name.eq_ignore_ascii_case("sw-linear") {
            return Some(ScenarioSpec::table2()[6].clone().sw_linear());
        }
        if name.eq_ignore_ascii_case("sw-queue") {
            return Some(ScenarioSpec::table2()[6].clone());
        }
        ScenarioSpec::all()
            .into_iter()
            .find(|s| s.name.eq_ignore_ascii_case(name))
    }

    /// The `SW-linear` variant of Fig. 4 (same topology/params, linear
    /// costs on both planes).
    pub fn sw_linear(mut self) -> ScenarioSpec {
        self.name = "sw-linear";
        self.link_kind = CostKind::Linear;
        self.comp_kind = CostKind::Linear;
        self
    }

    /// A reduced-size variant that fits the `small` AOT class
    /// (N ≤ 32, S ≤ 48) — used by the accelerated example and parity tests.
    pub fn shrunk(mut self, num_tasks: usize) -> ScenarioSpec {
        self.num_tasks = num_tasks;
        self
    }

    /// Instantiate the scenario deterministically from `seed`.
    pub fn build(&self, seed: u64) -> Scenario {
        let mut rng = Pcg::with_stream(seed, 0xcec + self.topology as u64);
        let graph = self.topology.build(&mut rng);
        let n = graph.node_count();
        let e = graph.edge_count();

        // result ratios a_m ~ Exp(0.5) ∩ [0.1, 5]
        let result_ratio: Vec<f64> = (0..self.num_types)
            .map(|_| rng.exponential_trunc(0.5, 0.1, 5.0))
            .collect();
        // weights w_im ~ U[1,5]
        let comp_weight: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..self.num_types).map(|_| rng.uniform(1.0, 5.0)).collect())
            .collect();

        // tasks: uniform type + destination, |R| distinct sources
        let mut tasks = Vec::with_capacity(self.num_tasks);
        let mut input_rate = Vec::with_capacity(self.num_tasks);
        for _ in 0..self.num_tasks {
            let dest = rng.below(n);
            let ctype = rng.below(self.num_types);
            tasks.push(Task { dest, ctype });
            let mut rates = vec![0.0; n];
            for src in rng.choose_distinct(n, self.sources_per_task.min(n)) {
                rates[src] = rng.uniform(self.r_min, self.r_max);
            }
            input_rate.push(rates);
        }

        // link costs: d_ij ~ U[0, 2·d̄] (floored slightly away from 0 so
        // queue capacities are usable)
        let mut link_cost: Vec<CostFn> = (0..e)
            .map(|_| {
                let d = rng.uniform(0.05 * self.link_mean, 2.0 * self.link_mean);
                match self.link_kind {
                    CostKind::Linear => CostFn::Linear { unit: d.max(1e-3) },
                    CostKind::Queue => CostFn::Queue { cap: d.max(1e-3) },
                }
            })
            .collect();

        // computation costs: Exp(s̄) for Queue, U[0, 2·s̄] for Linear
        let mut comp_cost: Vec<CostFn> = (0..n)
            .map(|_| match self.comp_kind {
                CostKind::Linear => CostFn::Linear {
                    unit: rng.uniform(0.0, 2.0 * self.comp_mean).max(1e-3),
                },
                CostKind::Queue => CostFn::Queue {
                    cap: rng.exponential(self.comp_mean).max(1e-3),
                },
            })
            .collect();

        // --- feasibility guard 1: local computation must be possible ---
        for i in 0..n {
            let mut load = 0.0;
            for (s, task) in tasks.iter().enumerate() {
                load += comp_weight[i][task.ctype] * input_rate[s][i];
            }
            if let CostFn::Queue { cap } = comp_cost[i] {
                if cap <= 1.25 * load {
                    comp_cost[i] = CostFn::Queue {
                        cap: 1.25 * load + rng.exponential(self.comp_mean),
                    };
                }
            }
        }

        let mut net = Network {
            graph,
            tasks,
            num_types: self.num_types,
            input_rate,
            result_ratio,
            comp_weight,
            link_cost: link_cost.clone(),
            comp_cost,
        };

        // --- feasibility guard 2: finite initial cost ---
        for _round in 0..40 {
            let phi0 = Strategy::local_compute_init(&net);
            let t0 = crate::model::flows::compute_flows(&net, &phi0)
                .map(|f| f.total_cost)
                .unwrap_or(f64::INFINITY);
            if t0.is_finite() {
                break;
            }
            for c in link_cost.iter_mut() {
                if let CostFn::Queue { cap } = c {
                    *cap *= 1.3;
                }
            }
            net.link_cost = link_cost.clone();
        }

        net.assert_valid();
        debug_assert!(net.local_computation_feasible());
        Scenario {
            name: self.name.to_string(),
            net,
            servers: Vec::new(),
            seed,
        }
    }
}

/// A built scenario: the network plus metadata.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: String,
    pub net: Network,
    /// Designated "major servers" (Fig. 5a) — empty unless built by
    /// [`connected_er_servers`].
    pub servers: Vec<usize>,
    pub seed: u64,
}

/// The refined Connected-ER instance of Fig. 5a: 4 designated major
/// servers with boosted computation capacity; task destinations are drawn
/// from the servers (users fetch results at service points), and `S1 =
/// servers[0]` is the node failed at iteration 100 in Fig. 5b.
pub fn connected_er_servers(seed: u64) -> Scenario {
    let spec = &ScenarioSpec::table2()[0];
    let mut rng = Pcg::with_stream(seed, 0x5e71);
    let graph = connected_er(20, 40, &mut rng);
    let n = graph.node_count();

    // spread servers: pick 4 distinct nodes
    let servers = rng.choose_distinct(n, 4);

    let result_ratio: Vec<f64> = (0..spec.num_types)
        .map(|_| rng.exponential_trunc(0.5, 0.1, 5.0))
        .collect();
    let comp_weight: Vec<Vec<f64>> = (0..n)
        .map(|_| (0..spec.num_types).map(|_| rng.uniform(1.0, 5.0)).collect())
        .collect();

    let mut tasks = Vec::new();
    let mut input_rate = Vec::new();
    for _ in 0..spec.num_tasks {
        let dest = *rng.pick(&servers);
        let ctype = rng.below(spec.num_types);
        tasks.push(Task { dest, ctype });
        let mut rates = vec![0.0; n];
        for src in rng.choose_distinct(n, spec.sources_per_task) {
            rates[src] = rng.uniform(spec.r_min, spec.r_max);
        }
        input_rate.push(rates);
    }

    let mut link_cost: Vec<CostFn> = (0..graph.edge_count())
        .map(|_| CostFn::Queue {
            cap: rng.uniform(0.05 * spec.link_mean, 2.0 * spec.link_mean),
        })
        .collect();
    let mut comp_cost: Vec<CostFn> = (0..n)
        .map(|i| {
            let base = rng.exponential(spec.comp_mean).max(1e-3);
            let boost = if servers.contains(&i) { 4.0 } else { 1.0 };
            CostFn::Queue { cap: base * boost }
        })
        .collect();

    for i in 0..n {
        let mut load = 0.0;
        for (s, task) in tasks.iter().enumerate() {
            load += comp_weight[i][task.ctype] * input_rate[s][i];
        }
        if let CostFn::Queue { cap } = comp_cost[i] {
            if cap <= 1.25 * load {
                comp_cost[i] = CostFn::Queue {
                    cap: 1.25 * load + rng.exponential(spec.comp_mean),
                };
            }
        }
    }

    let mut net = Network {
        graph,
        tasks,
        num_types: spec.num_types,
        input_rate,
        result_ratio,
        comp_weight,
        link_cost: link_cost.clone(),
        comp_cost,
    };
    for _ in 0..40 {
        let phi0 = Strategy::local_compute_init(&net);
        let finite = crate::model::flows::compute_flows(&net, &phi0)
            .map(|f| f.total_cost.is_finite())
            .unwrap_or(false);
        if finite {
            break;
        }
        for c in link_cost.iter_mut() {
            if let CostFn::Queue { cap } = c {
                *cap *= 1.3;
            }
        }
        net.link_cost = link_cost.clone();
    }
    net.assert_valid();

    Scenario {
        name: "connected-er-servers".to_string(),
        net,
        servers,
        seed,
    }
}

/// Build a small scenario that fits the `small` AOT size class — the
/// workhorse of the accelerated example and XLA parity tests.
pub fn small_scenario(seed: u64) -> Scenario {
    let spec = ScenarioSpec::table2()[3].clone(); // Abilene: 11 nodes
    let mut sc = spec.build(seed);
    sc.name = "abilene-small".to_string();
    sc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::flows::compute_flows;

    #[test]
    fn table2_sizes_match_paper() {
        let specs = ScenarioSpec::table2();
        let expect = [
            ("connected-er", 20, 40, 15),
            ("balanced-tree", 15, 14, 20),
            ("fog", 19, 33, 30), // |E|=33 vs paper's 30: see topology.rs fog()
            ("abilene", 11, 14, 10),
            ("lhc", 16, 31, 30),
            ("geant", 22, 33, 40),
            ("sw", 100, 320, 120),
        ];
        for (spec, (name, v, e_links, s)) in specs.iter().zip(expect) {
            assert_eq!(spec.name, name);
            let sc = spec.build(7);
            assert_eq!(sc.net.n(), v, "{name} |V|");
            assert_eq!(sc.net.e(), 2 * e_links, "{name} |E|");
            assert_eq!(sc.net.s(), s, "{name} |S|");
        }
    }

    #[test]
    fn instances_feasible_and_deterministic() {
        for spec in ScenarioSpec::table2().iter().take(6) {
            let a = spec.build(42);
            let b = spec.build(42);
            assert_eq!(a.net.tasks, b.net.tasks, "{} nondeterministic", spec.name);
            assert!(a.net.local_computation_feasible(), "{}", spec.name);
            let phi0 = Strategy::local_compute_init(&a.net);
            let t0 = compute_flows(&a.net, &phi0).unwrap().total_cost;
            assert!(t0.is_finite(), "{} infinite initial cost", spec.name);
        }
    }

    #[test]
    fn different_seeds_differ() {
        let spec = &ScenarioSpec::table2()[0];
        let a = spec.build(1);
        let b = spec.build(2);
        assert_ne!(a.net.tasks, b.net.tasks);
    }

    #[test]
    fn sw_linear_variant() {
        let spec = ScenarioSpec::by_name("sw-linear").unwrap();
        assert_eq!(spec.link_kind, CostKind::Linear);
        assert_eq!(spec.comp_kind, CostKind::Linear);
        let sc = spec.build(3);
        assert!(matches!(sc.net.link_cost[0], CostFn::Linear { .. }));
    }

    #[test]
    fn by_name_lookup() {
        assert!(ScenarioSpec::by_name("geant").is_some());
        assert!(ScenarioSpec::by_name("GEANT").is_some());
        assert!(ScenarioSpec::by_name("nope").is_none());
    }

    #[test]
    fn extended_library_sizes() {
        let expect = [
            ("grid-torus", 20, 40, 20),
            ("scale-free", 25, 47, 25),
            ("fat-tree", 20, 32, 20),
        ];
        for (name, v, e_links, s) in expect {
            let spec = ScenarioSpec::by_name(name).unwrap();
            let sc = spec.build(7);
            assert_eq!(sc.net.n(), v, "{name} |V|");
            assert_eq!(sc.net.e(), 2 * e_links, "{name} |E|");
            assert_eq!(sc.net.s(), s, "{name} |S|");
            assert!(sc.net.local_computation_feasible(), "{name}");
        }
        assert_eq!(ScenarioSpec::all().len(), 10);
    }

    #[test]
    fn servers_scenario_properties() {
        let sc = connected_er_servers(5);
        assert_eq!(sc.servers.len(), 4);
        // all destinations are servers
        for t in &sc.net.tasks {
            assert!(sc.servers.contains(&t.dest));
        }
        let phi0 = Strategy::local_compute_init(&sc.net);
        assert!(compute_flows(&sc.net, &phi0)
            .unwrap()
            .total_cost
            .is_finite());
    }

    #[test]
    fn small_scenario_fits_small_class() {
        let sc = small_scenario(9);
        assert!(sc.net.n() <= 32);
        assert!(sc.net.s() <= 48);
    }

    #[test]
    fn a_m_range_respected() {
        let sc = ScenarioSpec::table2()[0].build(11);
        for &a in &sc.net.result_ratio {
            assert!((0.1..=5.0).contains(&a));
        }
    }
}
