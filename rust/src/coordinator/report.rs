//! Experiment reporting: machine-readable JSON/CSV under `results/` plus
//! the paper-style normalized bar rendering used by the benches.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;
use crate::util::table::{bar, fnum, Table};

/// A labelled series of (x, y) points — one line of Fig. 5c/5d.
#[derive(Clone, Debug)]
pub struct Series {
    pub label: String,
    pub x: Vec<f64>,
    pub y: Vec<f64>,
}

impl Series {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("label", Json::Str(self.label.clone()))
            .set("x", Json::from_f64_slice(&self.x))
            .set("y", Json::from_f64_slice(&self.y));
        o
    }
}

/// Ensure `results/` exists and return the path for `name`.
pub fn results_path(name: &str) -> Result<PathBuf> {
    let dir = std::env::var_os("CECFLOW_RESULTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("results"));
    std::fs::create_dir_all(&dir).with_context(|| format!("creating {dir:?}"))?;
    Ok(dir.join(name))
}

/// Write a JSON document to `results/<name>`.
pub fn write_json(name: &str, doc: &Json) -> Result<PathBuf> {
    let path = results_path(name)?;
    std::fs::write(&path, doc.pretty()).with_context(|| format!("writing {path:?}"))?;
    Ok(path)
}

/// Write a CSV file (header + rows) to `results/<name>`.
pub fn write_csv(name: &str, header: &[&str], rows: &[Vec<String>]) -> Result<PathBuf> {
    let path = results_path(name)?;
    let mut text = header.join(",");
    text.push('\n');
    for row in rows {
        text.push_str(&row.join(","));
        text.push('\n');
    }
    std::fs::write(&path, text).with_context(|| format!("writing {path:?}"))?;
    Ok(path)
}

/// Render the Fig. 4-style normalized bars: one block per scenario, bars
/// scaled to the worst algorithm in that scenario (matching the paper's
/// per-scenario normalization).
pub fn render_normalized_bars(
    scenario_names: &[String],
    algo_names: &[String],
    // costs[scenario][algo]
    costs: &[Vec<f64>],
) -> String {
    let mut out = String::new();
    for (si, sname) in scenario_names.iter().enumerate() {
        let worst = costs[si]
            .iter()
            .cloned()
            .filter(|c| c.is_finite())
            .fold(0.0f64, f64::max);
        out.push_str(&format!("\n{sname}\n"));
        for (ai, aname) in algo_names.iter().enumerate() {
            let c = costs[si][ai];
            let norm = if worst > 0.0 { c / worst } else { 0.0 };
            out.push_str(&format!(
                "  {aname:<6} |{}| {:.3}  (T = {})\n",
                bar(c, worst, 34),
                norm,
                fnum(c)
            ));
        }
    }
    out
}

/// Render a plain table of series values (Fig. 5c/5d text form).
pub fn render_series_table(x_label: &str, series: &[Series]) -> String {
    let mut header = vec![x_label];
    let labels: Vec<&str> = series.iter().map(|s| s.label.as_str()).collect();
    header.extend(labels);
    let mut t = Table::new(&header);
    if let Some(first) = series.first() {
        for (i, &x) in first.x.iter().enumerate() {
            let mut row = vec![fnum(x)];
            for s in series {
                row.push(fnum(s.y[i]));
            }
            t.row(row);
        }
    }
    t.render()
}

/// Serialize a whole figure (several series) to JSON.
pub fn figure_json(title: &str, series: &[Series]) -> Json {
    let mut o = Json::obj();
    o.set("title", Json::Str(title.to_string())).set(
        "series",
        Json::Arr(series.iter().map(Series::to_json).collect()),
    );
    o
}

/// Write a line-chart SVG for a figure's series to `results/<name>`.
pub fn write_series_svg(
    name: &str,
    title: &str,
    x_label: &str,
    y_label: &str,
    series: &[Series],
) -> Result<PathBuf> {
    let lines: Vec<crate::util::svg::Line> = series
        .iter()
        .map(|s| crate::util::svg::Line {
            label: &s.label,
            points: s.x.iter().cloned().zip(s.y.iter().cloned()).collect(),
        })
        .collect();
    let svg = crate::util::svg::line_chart(title, x_label, y_label, &lines);
    let path = results_path(name)?;
    std::fs::write(&path, svg).with_context(|| format!("writing {path:?}"))?;
    Ok(path)
}

/// Write a Fig. 4-style grouped-bar SVG to `results/<name>`.
pub fn write_bars_svg(
    name: &str,
    title: &str,
    groups: &[String],
    series: &[String],
    values: &[Vec<f64>],
) -> Result<PathBuf> {
    let svg = crate::util::svg::grouped_bars(title, groups, series, values);
    let path = results_path(name)?;
    std::fs::write(&path, svg).with_context(|| format!("writing {path:?}"))?;
    Ok(path)
}

/// Quick existence check used by tests.
pub fn exists(path: &Path) -> bool {
    path.exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bars_render_normalized() {
        let out = render_normalized_bars(
            &["scen".into()],
            &["sgp".into(), "lpr".into()],
            &[vec![1.0, 2.0]],
        );
        assert!(out.contains("scen"));
        assert!(out.contains("sgp"));
        assert!(out.contains("1.000")); // lpr normalized to 1
        assert!(out.contains("0.500")); // sgp at half
    }

    #[test]
    fn bars_handle_infinite_costs() {
        let out = render_normalized_bars(
            &["s".into()],
            &["a".into(), "b".into()],
            &[vec![f64::INFINITY, 2.0]],
        );
        assert!(out.contains("inf"));
    }

    #[test]
    fn series_table_renders() {
        let s = Series {
            label: "sgp".into(),
            x: vec![1.0, 2.0],
            y: vec![10.0, 20.0],
        };
        let txt = render_series_table("scale", &[s]);
        assert!(txt.contains("scale"));
        assert!(txt.contains("sgp"));
        assert!(txt.lines().count() >= 4);
    }

    #[test]
    fn json_roundtrip_of_figure() {
        let s = Series {
            label: "x".into(),
            x: vec![0.5],
            y: vec![1.5],
        };
        let doc = figure_json("fig", &[s]);
        let parsed = Json::parse(&doc.dump()).unwrap();
        assert_eq!(parsed.get("title").as_str(), Some("fig"));
        assert_eq!(
            parsed.get("series").as_arr().unwrap()[0]
                .get("label")
                .as_str(),
            Some("x")
        );
    }

    #[test]
    fn csv_written_to_results() {
        std::env::set_var("CECFLOW_RESULTS", std::env::temp_dir().join("cecflow-res-test"));
        let p = write_csv("t.csv", &["a", "b"], &[vec!["1".into(), "2".into()]]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
        std::env::remove_var("CECFLOW_RESULTS");
    }
}
