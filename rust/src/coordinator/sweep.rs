//! The sweep grid *definition*: `scenario × seed × algorithm × backend ×
//! schedule` cells, aggregated into one comparable [`SweepReport`]. This
//! is the machinery behind the `cecflow sweep` subcommand and
//! `benches/sweep.rs`.
//!
//! Execution is delegated to the layered engine in
//! [`super::exec`]: the grid layer owns index assignment and identity
//! hashing, the pool layer runs cells on worker threads, the shard layer
//! spawns `--shard-worker i/n` child processes (with bounded retry and
//! work re-stealing via `--shard-retries` / `--steal-cells`), and the
//! artifact layer loads and merges `--shard i/n --out f.json` reports
//! index- and hash-verified. This module only defines *what* a cell is
//! (identity and execution); the report data model — aggregation, the
//! fingerprint, serde, merge — lives in [`super::sweep_report`].
//!
//! Determinism is a hard contract, pinned by
//! `rust/tests/sweep_determinism.rs` and `rust/tests/sweep_shard.rs`:
//! every cell derives all randomness from its own `(scenario, seed)` pair
//! and results carry their global grid index, so per-cell results are
//! identical for any worker count, shard count, and retry/re-steal
//! history — only wall-clock timings vary. Cells with a non-static
//! [`PatternSchedule`] run the dynamic task-pattern engine
//! ([`super::dynamics`]) warm-started and record per-epoch final costs.

use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::model::flows::compute_flows;
use crate::model::strategy::Strategy;
use crate::sim::{self, ArrivalSpec, SimConfig, SimEpoch, SimPlan};
use crate::util::json::Json;

use super::dynamics::{AdaptiveRunner, PatternSchedule};
use super::exec::grid::{Grid, GridCell, GridHasher};
use super::exec::{pool, shard};
use super::store::{self, FsStore, StoredRun, StrategyStore};
use super::{
    build_scenario_network, metrics, run_algorithm_with_backend_warm_ws, Algorithm, CellBackend,
    RunConfig,
};
use crate::algo::OptWorkspace;

pub use super::config::{parse_algorithms, parse_backends, parse_scenarios, parse_seeds, MAX_SEED};
pub use super::dynamics::parse_schedules;
pub use super::exec::grid::shard_indices as shard_cell_indices;
pub use super::exec::shard::{
    done_line, error_line, parse_cell_list, parse_shard_arg, ShardOptions,
};
pub use super::sweep_report::{CellFingerprint, GroupSummary, SweepReport};

/// Opt-in request-level simulation of every cell's converged strategy
/// (`cecflow sweep --sim-requests N`): after a cell's optimizer run, the
/// discrete-event engine ([`crate::sim::tasks`]) releases `requests`
/// stochastic requests through the strategy's routing splits and records
/// streaming sojourn quantiles into [`CellSim`].
///
/// The config is part of the sweep's identity
/// ([`spec_grid_hash`]): reports with and without simulation — or with
/// different simulation parameters — refuse to merge, because their cells
/// are not comparable. Restricted by [`validate_spec`] to static
/// schedules (dynamic cells re-optimize per epoch; simulate those through
/// `cecflow simulate --pattern` instead) and to algorithms that produce a
/// strategy ([`Algorithm::supports_simulation`] — the one-shot LPR bound
/// does not).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SimSweepConfig {
    /// Requests released per cell.
    pub requests: u64,
    /// Arrival process (`--sim-arrivals`, default Poisson).
    pub arrivals: ArrivalSpec,
    /// Warm-up fraction excluded from the sojourn sketch, in `[0, 1)`.
    pub warmup: f64,
    /// Closed-loop validation tolerance (`--sim-validate`): when set, each
    /// simulated cell is compared against its analytic steady state
    /// ([`crate::sim::validate`]) and the headline divergence metrics ride
    /// along in [`CellSim::divergence`]. An alarmed cell is a *measured
    /// result*, not a sweep failure.
    pub validate: Option<f64>,
    /// Per-queue FIFO capacity (`--sim-queue-cap`): when set, every
    /// simulated server admits at most K requests (M/M/1/K semantics) and
    /// cells grow drop/blocking columns. Part of the grid hash — capped
    /// and uncapped artifacts refuse to merge. `None` (the default)
    /// reproduces the unbounded-FIFO sweep byte-for-byte.
    pub queue_cap: Option<u64>,
}

impl Default for SimSweepConfig {
    fn default() -> Self {
        SimSweepConfig {
            requests: 20_000,
            arrivals: ArrivalSpec::default(),
            warmup: 0.05,
            validate: None,
            queue_cap: None,
        }
    }
}

/// Tail-latency digest of one cell's request-level simulation: sojourn
/// quantiles (seconds) plus the mean, straight from
/// [`crate::sim::Telemetry`]. Carried bit-exactly through the shard
/// protocol and report artifacts, and part of the fingerprint.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CellSim {
    pub p50: f64,
    pub p99: f64,
    pub p999: f64,
    pub mean: f64,
    /// Closed-loop divergence digest when the spec enabled
    /// `--sim-validate`; `None` otherwise.
    pub divergence: Option<CellDivergence>,
    /// Requests dropped at full per-queue FIFOs when the spec enabled
    /// `--sim-queue-cap`; `None` on uncapped sweeps (whose artifacts stay
    /// byte-identical to the pre-admission-control format).
    pub queue_dropped: Option<u64>,
    /// Worst per-server simulated blocking rate (`blocked/offered`) when
    /// the spec enabled `--sim-queue-cap`; `None` otherwise.
    pub max_blocking: Option<f64>,
}

/// Headline numbers of one cell's closed-loop validation
/// ([`crate::sim::validate`]): aggregate and worst per-server relative
/// error, plus whether the hard alarm fired. Carried bit-exactly through
/// the shard protocol and report artifacts, and part of the fingerprint.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CellDivergence {
    /// `rel_diff` of analytic `T/λ` vs simulated mean sojourn.
    pub mean_rel_err: f64,
    /// Worst per-server occupancy error among loaded servers.
    pub max_server_rel_err: f64,
    /// The validator's alarm verdict (saturation, overload drops, empty
    /// telemetry, or tolerance breach).
    pub alarm: bool,
}

/// Strategy-store consultation outcome of one cell, recorded when the
/// sweep ran with a cache ([`SweepSpec::cache`]) on an algorithm that can
/// reuse a stored strategy ([`Algorithm::supports_warm_start`]); `None`
/// otherwise. Carried bit-exactly through the shard protocol and report
/// artifacts, but — like wall times and worker counts — excluded from the
/// fingerprint: whether a cell's result came out of the store must not
/// change what the sweep *measured*.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CellCache {
    /// The store supplied an entry that passed re-pricing verification
    /// (see [`StoredRun::price_bits`]), and the cell adopted its stored
    /// cold trajectory without solving.
    pub hit: bool,
    /// Optimizer iterations the hit avoided executing (the stored
    /// trajectory's length); `0` on a miss. The cell's reported
    /// `iterations` stays the canonical cold count either way — this
    /// field is where the saved work shows up.
    pub iters_saved: usize,
}

/// A sweep specification: the cell grid is the cross product
/// `scenarios × seeds × algorithms × backends × schedules` (non-SGP
/// algorithms only pair with [`CellBackend::Sparse`] — they have no dense
/// path — and non-static schedules only pair with the iterative
/// [`Algorithm::supports_dynamic`] algorithms), every cell run at
/// `rate_scale` under the same stopping rule.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    pub scenarios: Vec<String>,
    pub seeds: Vec<u64>,
    pub algorithms: Vec<Algorithm>,
    /// Dense-evaluation routes to sweep SGP cells over. `[Sparse]` (the
    /// default) reproduces the pre-routing grid exactly.
    pub backends: Vec<CellBackend>,
    /// Task-pattern schedules to sweep over. `[static]` (the default)
    /// reproduces the pre-dynamics grid exactly; other entries run the
    /// warm-started dynamic engine and report the last epoch's cost.
    pub schedules: Vec<PatternSchedule>,
    pub rate_scale: f64,
    pub run: RunConfig,
    /// Request-level simulation of each cell's converged strategy
    /// (`None`, the default, reproduces the analytic-only sweep exactly).
    pub sim: Option<SimSweepConfig>,
    /// Strategy-store directory (`--cache-dir`): when set, every
    /// warm-startable cell consults an [`FsStore`] there before solving
    /// and inserts its converged run after, and cell records grow cache
    /// columns plus the converged strategy. `None` (the default)
    /// reproduces the store-less sweep byte-for-byte.
    pub cache: Option<String>,
}

impl Default for SweepSpec {
    fn default() -> Self {
        SweepSpec {
            scenarios: vec!["abilene".to_string(), "connected-er".to_string()],
            seeds: vec![1, 2, 3],
            algorithms: vec![Algorithm::Sgp, Algorithm::Gp, Algorithm::Lpr],
            backends: vec![CellBackend::Sparse],
            schedules: vec![PatternSchedule::static_()],
            rate_scale: 1.0,
            run: RunConfig::quick(),
            sim: None,
            cache: None,
        }
    }
}

/// One grid cell: a scenario instance (name + seed) optimized by one
/// algorithm through one dense-evaluation route, under one task-pattern
/// schedule.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SweepCell {
    pub scenario: String,
    pub seed: u64,
    pub algorithm: Algorithm,
    pub backend: CellBackend,
    pub schedule: PatternSchedule,
}

impl GridCell for SweepCell {
    fn describe(&self, index: usize) -> String {
        format!(
            "sweep cell {index} ({} seed {} algo {} backend {} schedule {})",
            self.scenario,
            self.seed,
            self.algorithm.name(),
            self.backend.name(),
            self.schedule.label()
        )
    }

    fn write_identity(&self, h: &mut GridHasher) {
        h.eat(self.scenario.as_bytes());
        h.eat(&[0]);
        h.eat(&self.seed.to_le_bytes());
        h.eat(self.algorithm.name().as_bytes());
        h.eat(&[0]);
        h.eat(self.backend.name().as_bytes());
        h.eat(&[0]);
        // the schedule axis is identity-relevant: shard artifacts from
        // different schedule grids must never merge silently
        h.eat(self.schedule.label().as_bytes());
        h.eat(&[0xff]);
    }
}

impl SweepSpec {
    /// The cell grid in canonical order: scenarios outermost, then seeds,
    /// then algorithms, then backends, then schedules. This order is part
    /// of the determinism contract — reports compare cell-by-cell across
    /// runs, worker counts and shard counts. Non-SGP × non-`Sparse`
    /// combinations are skipped (no dense path exists for the baselines),
    /// as are non-static schedules on algorithms without a dynamic path
    /// ([`Algorithm::supports_dynamic`]).
    pub fn cells(&self) -> Vec<SweepCell> {
        let mut out = Vec::new();
        for scenario in &self.scenarios {
            for &seed in &self.seeds {
                for &algorithm in &self.algorithms {
                    for &backend in &self.backends {
                        if backend != CellBackend::Sparse && algorithm != Algorithm::Sgp {
                            continue;
                        }
                        for &schedule in &self.schedules {
                            if !schedule.is_static() && !algorithm.supports_dynamic() {
                                continue;
                            }
                            out.push(SweepCell {
                                scenario: scenario.clone(),
                                seed,
                                algorithm,
                                backend,
                                schedule,
                            });
                        }
                    }
                }
            }
        }
        out
    }

    /// The cell grid wrapped for the execution engine.
    pub fn grid(&self) -> Grid<SweepCell> {
        Grid::new(self.cells())
    }
}

/// The outcome of one cell, tagged with its global grid index so shard
/// outputs can be reassembled in canonical order.
#[derive(Clone, Debug)]
pub struct CellResult {
    /// Position of this cell in [`SweepSpec::cells`] order.
    pub index: usize,
    pub cell: SweepCell,
    pub final_cost: f64,
    pub iterations: usize,
    pub iters_to_1pct: usize,
    pub wall_seconds: f64,
    /// Per-epoch final costs of a dynamic (non-static-schedule) cell, in
    /// epoch order; empty for static cells. Carried bit-exactly through
    /// the shard protocol and report artifacts, and part of the
    /// fingerprint.
    pub epoch_costs: Vec<f64>,
    /// Simulated sojourn digest when the spec enabled request-level
    /// simulation ([`SweepSpec::sim`]); `None` otherwise.
    pub sim: Option<CellSim>,
    /// Strategy-store outcome when the spec ran with a cache
    /// ([`SweepSpec::cache`]) and the cell's algorithm can reuse a stored
    /// strategy; `None` otherwise. Excluded from the fingerprint.
    pub cache: Option<CellCache>,
    /// The cell's converged strategy, shipped through the shard protocol
    /// and report artifacts when the spec ran with a cache (bits-exact,
    /// digest-sealed — [`Strategy::to_json`]); `None` otherwise, keeping
    /// store-less artifacts byte-identical to earlier versions. Excluded
    /// from the fingerprint.
    pub phi: Option<Strategy>,
}

/// Content address of one static cell's converged run in a
/// [`StrategyStore`]: the *pre-solve* prefix of the cell fingerprint —
/// cell identity (scenario, seed, algorithm, backend, schedule) plus
/// everything else that determines the solve (rate scale, stopping rule)
/// — hashed with the store-format salt ([`store::key_hasher`]). The
/// post-solve fingerprint proper cannot address the store: the consult
/// happens before any solving.
fn cell_store_key(cell: &SweepCell, spec: &SweepSpec) -> u64 {
    let mut h = store::key_hasher();
    cell.write_identity(&mut h);
    h.eat(&spec.rate_scale.to_bits().to_le_bytes());
    h.eat(&(spec.run.max_iters as u64).to_le_bytes());
    h.eat(&spec.run.tol.to_bits().to_le_bytes());
    h.eat(&(spec.run.patience as u64).to_le_bytes());
    h.finish()
}

/// Open the spec's strategy store, if any ([`SweepSpec::cache`]).
fn open_store(spec: &SweepSpec) -> Result<Option<FsStore>> {
    spec.cache
        .as_deref()
        .map(|dir| FsStore::open(Path::new(dir)))
        .transpose()
}

fn run_cell(
    index: usize,
    cell: &SweepCell,
    spec: &SweepSpec,
    store: Option<&dyn StrategyStore>,
) -> Result<CellResult> {
    if !cell.schedule.is_static() {
        return run_dynamic_cell(index, cell, spec);
    }
    let net = build_scenario_network(&cell.scenario, cell.seed, spec.rate_scale)?;
    let start = Instant::now();
    // Only algorithms that can reuse an arbitrary feasible strategy
    // participate in the store; other cells record no cache outcome.
    let store = store.filter(|_| cell.algorithm.supports_warm_start());
    let key = store.map(|_| cell_store_key(cell, spec));
    let mut adopted: Option<StoredRun> = None;
    let mut cache = None;
    if let (Some(s), Some(key)) = (store, key) {
        match s.load(key) {
            Some(entry) if entry.verifies_on(&net) => {
                cache = Some(CellCache {
                    hit: true,
                    iters_saved: entry.iterations(),
                });
                adopted = Some(entry);
            }
            Some(_) => {
                // a verification miss: the entry parsed but does not
                // reproduce this cell's costs — stale key collision or a
                // changed scenario builder; re-run cold and overwrite
                eprintln!(
                    "warning: strategy store: entry {key:016x} failed re-pricing \
                     verification; re-running cold"
                );
                cache = Some(CellCache {
                    hit: false,
                    iters_saved: 0,
                });
            }
            None => {
                cache = Some(CellCache {
                    hit: false,
                    iters_saved: 0,
                });
            }
        }
    }
    let (final_cost, iterations, iters_to_1pct, phi) = match adopted {
        // A verified hit adopts the stored cold trajectory without
        // solving: final cost, iteration count and the 1% marker are the
        // cold run's own (bits-exact), so the fingerprint cannot tell a
        // hit from a cold solve.
        Some(entry) => (
            entry.final_cost(),
            entry.iterations(),
            entry.iters_to_1pct,
            Some(entry.phi),
        ),
        None => {
            // One workspace per cell (cells may run on different worker
            // threads; a workspace is single-threaded state) — every
            // iteration of the cell's run reuses the same arena.
            let mut ws = OptWorkspace::new();
            let out = run_algorithm_with_backend_warm_ws(
                &net,
                cell.algorithm,
                cell.backend,
                &spec.run,
                None,
                &mut ws,
            )?;
            let iters_to_1pct = metrics::iters_to_1pct(&out.costs);
            if let (Some(s), Some(key), Some(phi)) = (store, key, out.phi.as_ref()) {
                // best-effort insert. A saturated run is not stored: its
                // non-finite price bits are a brittle verification seal
                // and there is nothing worth warming from.
                match compute_flows(&net, phi) {
                    Ok(f) if f.total_cost.is_finite() => s.save(
                        key,
                        &StoredRun::capture(
                            &out.algorithm,
                            &out.costs,
                            iters_to_1pct,
                            f.total_cost,
                            phi,
                        ),
                    ),
                    _ => {}
                }
            }
            (out.final_cost, out.iterations, iters_to_1pct, out.phi)
        }
    };
    let final_cost = if final_cost.is_nan() {
        f64::INFINITY
    } else {
        final_cost
    };
    let sim = match &spec.sim {
        Some(cfg) => {
            let phi = phi.as_ref().with_context(|| {
                format!(
                    "algorithm {} produced no strategy to simulate",
                    cell.algorithm.name()
                )
            })?;
            let plan = SimPlan {
                epochs: vec![SimEpoch {
                    net,
                    phi: phi.clone(),
                }],
            };
            // seeded by the cell's own seed: the simulated columns obey the
            // same determinism contract as the analytic ones
            let telemetry = sim::simulate(
                &plan,
                &cfg.arrivals,
                &SimConfig {
                    requests: cfg.requests,
                    warmup: cfg.warmup,
                    seed: cell.seed,
                    queue_cap: cfg.queue_cap,
                    ..SimConfig::default()
                },
            )?;
            let divergence = match cfg.validate {
                Some(tol) => {
                    // an alarmed cell is a measured outcome of the grid,
                    // recorded in the artifact rather than failing the sweep
                    let ep = &plan.epochs[0];
                    let report = sim::validate(&ep.net, &ep.phi, &telemetry, tol)?;
                    Some(CellDivergence {
                        mean_rel_err: report.mean_rel_error,
                        max_server_rel_err: report.max_server_rel_error,
                        alarm: report.alarm,
                    })
                }
                None => None,
            };
            let (p50, p99, p999) = telemetry.tail();
            // capped columns exist exactly when the spec asked for a cap,
            // so uncapped artifacts keep their historical bytes
            let (queue_dropped, max_blocking) = match cfg.queue_cap {
                Some(_) => {
                    let rate = |blocked: &[u64], offered: &[u64]| {
                        blocked
                            .iter()
                            .zip(offered)
                            .filter(|&(_, &o)| o > 0)
                            .map(|(&b, &o)| b as f64 / o as f64)
                            .fold(0.0, f64::max)
                    };
                    let mb = rate(&telemetry.node_blocked, &telemetry.node_offered)
                        .max(rate(&telemetry.link_blocked, &telemetry.link_offered));
                    (Some(telemetry.queue_dropped), Some(mb))
                }
                None => (None, None),
            };
            Some(CellSim {
                p50,
                p99,
                p999,
                mean: telemetry.mean_sojourn(),
                divergence,
                queue_dropped,
                max_blocking,
            })
        }
        None => None,
    };
    Ok(CellResult {
        index,
        cell: cell.clone(),
        final_cost,
        iterations,
        iters_to_1pct,
        wall_seconds: start.elapsed().as_secs_f64(),
        epoch_costs: Vec::new(),
        sim,
        cache,
        // the strategy rides the artifact only for store-enabled cells;
        // store-less artifacts stay byte-identical to earlier versions
        phi: if store.is_some() { phi } else { None },
    })
}

/// A dynamic (non-static-schedule) cell: the warm-started adaptive run
/// over the cell's schedule. The reported cost is the *last* epoch's
/// converged cost, iterations count the whole run, iters-to-1% is the
/// **sum of the per-epoch counts** (each epoch measured against its own
/// converged cost — an index into a concatenated trajectory would
/// straddle epoch boundaries and measure nothing), and the per-epoch
/// finals ride along in [`CellResult::epoch_costs`].
fn run_dynamic_cell(index: usize, cell: &SweepCell, spec: &SweepSpec) -> Result<CellResult> {
    let start = Instant::now();
    let runner = AdaptiveRunner {
        algorithm: cell.algorithm,
        backend: cell.backend,
        warm: true,
        run: spec.run,
    };
    let trace = runner.run_scenario(&cell.scenario, cell.seed, spec.rate_scale, cell.schedule)?;
    let sanitize = |x: f64| if x.is_nan() { f64::INFINITY } else { x };
    let last = trace.epochs.last().expect("a schedule has at least 1 epoch");
    Ok(CellResult {
        index,
        cell: cell.clone(),
        final_cost: sanitize(last.final_cost),
        iterations: trace.epochs.iter().map(|e| e.iterations).sum(),
        iters_to_1pct: trace.epochs.iter().map(|e| e.iters_to_1pct).sum(),
        wall_seconds: start.elapsed().as_secs_f64(),
        epoch_costs: trace.epochs.iter().map(|e| sanitize(e.final_cost)).collect(),
        sim: None,
        // dynamic cells never consult a cross-session store: each epoch
        // warm-starts from its predecessor in-process, and an adopted
        // strategy would change the very trajectory being measured
        cache: None,
        phi: None,
    })
}

/// Deterministic identity of a sweep spec's result-relevant content:
/// [`Grid::identity_hash`] over the full cell grid plus the rate scale and
/// stopping rule. Stamped into every report this module produces so
/// [`SweepReport::merge`] can refuse shard artifacts from different
/// sweeps.
pub fn spec_grid_hash(spec: &SweepSpec) -> u64 {
    grid_hash_of(&spec.grid(), spec)
}

/// [`spec_grid_hash`] against an already-built grid — the entry points
/// below reuse the grid they execute instead of rebuilding the whole
/// cross product a second time just for the hash.
fn grid_hash_of(grid: &Grid<SweepCell>, spec: &SweepSpec) -> u64 {
    grid.identity_hash(|h| {
        h.eat(&spec.rate_scale.to_bits().to_le_bytes());
        h.eat(&(spec.run.max_iters as u64).to_le_bytes());
        h.eat(&spec.run.tol.to_bits().to_le_bytes());
        h.eat(&(spec.run.patience as u64).to_le_bytes());
        // the simulation config is identity-relevant: cells with and
        // without tail-latency columns (or with different request counts /
        // arrival processes) are not comparable, so their shard artifacts
        // must refuse to merge
        match &spec.sim {
            None => h.eat(&[0]),
            Some(sim) => {
                h.eat(&[1]);
                h.eat(&sim.requests.to_le_bytes());
                h.eat(sim.arrivals.label().as_bytes());
                h.eat(&[0]);
                h.eat(&sim.warmup.to_bits().to_le_bytes());
                // validated and unvalidated cells carry different digests
                match sim.validate {
                    None => h.eat(&[0]),
                    Some(tol) => {
                        h.eat(&[1]);
                        h.eat(&tol.to_bits().to_le_bytes());
                    }
                }
                // capped and uncapped cells measure different queues and
                // carry different columns; an uncapped spec eats NOTHING
                // here so pre-admission-control hashes are preserved
                if let Some(cap) = sim.queue_cap {
                    h.eat(&[2]);
                    h.eat(&cap.to_le_bytes());
                }
            }
        }
        // the cache axis folds in as an enabled bit only: cached and
        // uncached artifacts refuse to merge (their records differ —
        // cache columns and shipped strategies), but runs warming from
        // *different* directories are still the same sweep
        match &spec.cache {
            None => h.eat(&[0]),
            Some(_) => h.eat(&[1]),
        }
    })
}

/// Reject specs whose cells cannot round-trip through the JSON shard
/// protocol / report artifacts (seeds above 2^53 lose precision as f64),
/// and simulation configs the grid cannot honor: request-level simulation
/// needs a converged strategy per cell, so it is defined only for static
/// schedules (a dynamic cell re-optimizes per epoch — simulate those via
/// `cecflow simulate --pattern`) and for algorithms that produce one
/// ([`Algorithm::supports_simulation`]). These are hard errors rather
/// than silent cell skips: a skipped cell would change the grid between
/// sim and no-sim runs without the user asking for it.
/// The CLI seed parser enforces the seed bound too; this guard covers
/// library users.
fn validate_spec(spec: &SweepSpec) -> Result<()> {
    for &seed in &spec.seeds {
        anyhow::ensure!(
            seed <= MAX_SEED,
            "seed {seed} exceeds 2^53 and cannot round-trip through the sweep's JSON \
             protocol/artifacts"
        );
    }
    if let Some(sim) = &spec.sim {
        anyhow::ensure!(sim.requests >= 1, "simulation needs at least 1 request");
        anyhow::ensure!(
            sim.warmup.is_finite() && (0.0..1.0).contains(&sim.warmup),
            "simulation warm-up fraction must be in [0, 1), got {}",
            sim.warmup
        );
        if let Some(tol) = sim.validate {
            anyhow::ensure!(
                tol.is_finite() && tol > 0.0,
                "--sim-validate tolerance must be finite and positive, got {tol}"
            );
        }
        if let Some(cap) = sim.queue_cap {
            anyhow::ensure!(
                cap >= 1,
                "--sim-queue-cap must be ≥ 1 (a zero-capacity queue admits nothing)"
            );
        }
        for algo in &spec.algorithms {
            anyhow::ensure!(
                algo.supports_simulation(),
                "algorithm {} produces no strategy to simulate — drop it from --algos \
                 or drop --sim-requests",
                algo.name()
            );
        }
        for schedule in &spec.schedules {
            anyhow::ensure!(
                schedule.is_static(),
                "request-level sweep simulation is defined for static schedules only \
                 (got {}); use `cecflow simulate --pattern` for dynamic scenarios",
                schedule.label()
            );
        }
    }
    if let Some(dir) = &spec.cache {
        anyhow::ensure!(!dir.is_empty(), "--cache-dir needs a non-empty directory path");
    }
    Ok(())
}

fn nonempty(grid: &Grid<SweepCell>) -> Result<()> {
    anyhow::ensure!(
        !grid.is_empty(),
        "empty sweep: need at least one scenario, seed and algorithm"
    );
    Ok(())
}

/// Execute every cell of `spec` on up to `workers` threads (clamped to
/// `[1, #cells]`) and collect a [`SweepReport`]. Cell errors (e.g. an
/// unknown scenario name) fail the whole sweep with the offending cell
/// named.
pub fn run_sweep(spec: &SweepSpec, workers: usize) -> Result<SweepReport> {
    validate_spec(spec)?;
    let grid = spec.grid();
    nonempty(&grid)?;
    let grid_hash = grid_hash_of(&grid, spec);
    let cells = grid.indexed();
    let fs = open_store(spec)?;
    let st = fs.as_ref().map(|s| s as &dyn StrategyStore);
    let results = pool::run_cells(&cells, workers, |i, c| run_cell(i, c, spec, st), None)?;
    Ok(SweepReport {
        cells: results,
        workers: workers.clamp(1, cells.len()),
        grid_hash,
    })
}

/// Run one shard of `spec` in-process: the strided cells of
/// [`shard_cell_indices`], with `shard` 0-based. The report's cells carry
/// their *global* grid indices, so shard reports merge back into the
/// single-process report via [`SweepReport::merge`].
pub fn run_sweep_shard(
    spec: &SweepSpec,
    shard: usize,
    count: usize,
    workers: usize,
) -> Result<SweepReport> {
    run_sweep_shard_with(spec, shard, count, workers, |_| {})
}

/// [`run_sweep_shard`] with a completion hook: `on_cell` is called (from
/// worker threads) as each cell finishes — the `--shard-worker` mode
/// streams protocol lines through it.
pub fn run_sweep_shard_with<F>(
    spec: &SweepSpec,
    shard: usize,
    count: usize,
    workers: usize,
    on_cell: F,
) -> Result<SweepReport>
where
    F: Fn(&CellResult) + Sync,
{
    anyhow::ensure!(
        count >= 1 && shard < count,
        "shard index {shard} out of range for {count} shard(s)"
    );
    validate_spec(spec)?;
    let grid = spec.grid();
    nonempty(&grid)?;
    let grid_hash = grid_hash_of(&grid, spec);
    let mine = grid.shard(shard, count);
    if mine.is_empty() {
        // more shards than cells: this shard legitimately owns nothing
        return Ok(SweepReport {
            cells: Vec::new(),
            workers: 0,
            grid_hash,
        });
    }
    let fs = open_store(spec)?;
    let st = fs.as_ref().map(|s| s as &dyn StrategyStore);
    let results = pool::run_cells(&mine, workers, |i, c| run_cell(i, c, spec, st), Some(&on_cell))?;
    Ok(SweepReport {
        cells: results,
        workers: workers.clamp(1, mine.len()),
        grid_hash,
    })
}

/// Run an explicit set of global cell indices of `spec` — the
/// `--steal-cells` work-re-stealing mode: a replacement child re-runs
/// exactly the cells a failed shard left unfinished (see
/// [`super::exec::shard`]). Out-of-range indices are an error.
pub fn run_sweep_cells_with<F>(
    spec: &SweepSpec,
    indices: &[usize],
    workers: usize,
    on_cell: F,
) -> Result<SweepReport>
where
    F: Fn(&CellResult) + Sync,
{
    validate_spec(spec)?;
    let grid = spec.grid();
    nonempty(&grid)?;
    let grid_hash = grid_hash_of(&grid, spec);
    let mine = grid.subset(indices)?;
    let fs = open_store(spec)?;
    let st = fs.as_ref().map(|s| s as &dyn StrategyStore);
    let results = pool::run_cells(&mine, workers, |i, c| run_cell(i, c, spec, st), Some(&on_cell))?;
    Ok(SweepReport {
        cells: results,
        workers: workers.clamp(1, mine.len()),
        grid_hash,
    })
}

/// Serialize a finished cell as one `--shard-worker` protocol line
/// (compact JSON, no newline). The cost travels as exact bits
/// (`final_cost_bits`), so the parent's merged report is bit-identical to
/// an in-process run.
pub fn cell_line(cell: &CellResult) -> String {
    let mut o = cell.to_json();
    o.set("type", Json::Str("cell".to_string()));
    o.dump()
}

/// Reconstruct the `cecflow sweep` CLI flags describing `spec` — the
/// parent → child handoff of the process-sharded sweep. Every field that
/// affects cell results is encoded, so a child parsing these flags
/// rebuilds an identical grid and stopping rule.
pub fn spec_to_args(spec: &SweepSpec) -> Vec<String> {
    let join = |parts: Vec<String>| parts.join(",");
    let mut args = vec![
        "--scenarios".to_string(),
        spec.scenarios.join(","),
        "--seeds".to_string(),
        join(spec.seeds.iter().map(u64::to_string).collect()),
        "--algos".to_string(),
        join(spec.algorithms.iter().map(|a| a.name().to_string()).collect()),
        "--backends".to_string(),
        join(spec.backends.iter().map(|b| b.name().to_string()).collect()),
        "--schedules".to_string(),
        join(spec.schedules.iter().map(|s| s.label()).collect()),
        // f64 Display is the shortest round-tripping decimal, so the
        // child parses back the exact same value
        "--scale".to_string(),
        spec.rate_scale.to_string(),
        "--iters".to_string(),
        spec.run.max_iters.to_string(),
        "--tol".to_string(),
        spec.run.tol.to_string(),
        "--patience".to_string(),
        spec.run.patience.to_string(),
    ];
    if let Some(sim) = &spec.sim {
        args.push("--sim-requests".to_string());
        args.push(sim.requests.to_string());
        args.push("--sim-arrivals".to_string());
        args.push(sim.arrivals.label());
        args.push("--sim-warmup".to_string());
        args.push(sim.warmup.to_string());
        if let Some(tol) = sim.validate {
            args.push("--sim-validate".to_string());
            args.push(tol.to_string());
        }
        if let Some(cap) = sim.queue_cap {
            args.push("--sim-queue-cap".to_string());
            args.push(cap.to_string());
        }
    }
    if let Some(dir) = &spec.cache {
        // shard children share the parent's store directory: whichever
        // child solves a cell first persists it for every later run
        args.push("--cache-dir".to_string());
        args.push(dir.clone());
    }
    args
}

/// The sweep grid plugged into the engine's sharded orchestrator
/// ([`shard::run_sharded`]): spec flags for the parent → child handoff
/// plus identity-checked cell parsing.
struct SweepShardDriver<'a> {
    spec: &'a SweepSpec,
    grid: Grid<SweepCell>,
}

impl shard::ShardDriver for SweepShardDriver<'_> {
    type Item = CellResult;

    fn label(&self) -> &str {
        "sweep"
    }

    fn total(&self) -> usize {
        self.grid.len()
    }

    fn describe(&self, index: usize) -> String {
        self.grid.describe(index)
    }

    fn child_args(&self) -> Vec<String> {
        let mut args = vec!["sweep".to_string()];
        args.extend(spec_to_args(self.spec));
        args
    }

    fn parse_cell(&self, doc: &Json) -> Result<(usize, CellResult)> {
        let item = CellResult::from_json(doc)?;
        match self.grid.get(item.index) {
            Some(c) if *c == item.cell => Ok((item.index, item)),
            _ => bail!(
                "reported a result for a cell not in this grid (index {})",
                item.index
            ),
        }
    }
}

/// Run `spec` sharded across `opts.shards` child processes of the
/// `cecflow` binary at `exe` (the CLI passes `std::env::current_exe()`;
/// tests pass `env!("CARGO_BIN_EXE_cecflow")`), with bounded shard retry
/// and work re-stealing per [`ShardOptions::retries`].
///
/// Pinned by `rust/tests/sweep_shard.rs`: the merged report's
/// [`SweepReport::fingerprint`] equals the single-process [`run_sweep`]
/// fingerprint on the same spec — including after an injected mid-sweep
/// child kill recovered through re-stealing.
pub fn run_sweep_sharded(spec: &SweepSpec, exe: &Path, opts: &ShardOptions) -> Result<SweepReport> {
    validate_spec(spec)?;
    let grid = spec.grid();
    nonempty(&grid)?;
    let grid_hash = grid_hash_of(&grid, spec);
    let driver = SweepShardDriver { spec, grid };
    let cells = shard::run_sharded(&driver, exe, opts)?;
    Ok(SweepReport {
        cells,
        workers: opts.workers.max(1),
        grid_hash,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_grid_order_is_canonical_and_skips_invalid_pairings() {
        let spec = SweepSpec {
            scenarios: vec!["a".into(), "b".into()],
            seeds: vec![1, 2],
            algorithms: vec![Algorithm::Sgp, Algorithm::Lpr],
            backends: vec![CellBackend::Sparse],
            schedules: vec![PatternSchedule::static_()],
            rate_scale: 1.0,
            run: RunConfig::quick(),
            sim: None,
            cache: None,
        };
        let cells = spec.cells();
        assert_eq!(cells.len(), 8);
        assert_eq!(cells[0].scenario, "a");
        assert_eq!(cells[0].seed, 1);
        assert_eq!(cells[0].algorithm, Algorithm::Sgp);
        assert_eq!(cells[1].algorithm, Algorithm::Lpr);
        assert_eq!(cells[2].seed, 2);
        assert_eq!(cells[4].scenario, "b");

        // dense backends only pair with SGP; dynamic schedules only with
        // the iterative algorithms
        let spec = SweepSpec {
            scenarios: vec!["a".into()],
            seeds: vec![1],
            backends: vec![CellBackend::Sparse, CellBackend::Native],
            schedules: vec![
                PatternSchedule::static_(),
                PatternSchedule::parse("step:3:1.5").unwrap(),
            ],
            ..spec
        };
        let combos: Vec<(Algorithm, CellBackend, bool)> = spec
            .cells()
            .iter()
            .map(|c| (c.algorithm, c.backend, c.schedule.is_static()))
            .collect();
        assert_eq!(
            combos,
            vec![
                (Algorithm::Sgp, CellBackend::Sparse, true),
                (Algorithm::Sgp, CellBackend::Sparse, false),
                (Algorithm::Sgp, CellBackend::Native, true),
                (Algorithm::Sgp, CellBackend::Native, false),
                (Algorithm::Lpr, CellBackend::Sparse, true),
            ]
        );
    }

    #[test]
    fn grid_hash_tracks_every_axis_and_the_stopping_rule() {
        let base = SweepSpec::default();
        let h = spec_grid_hash(&base);
        assert_eq!(h, spec_grid_hash(&base.clone()), "hash must be stable");
        let mut other = base.clone();
        other.seeds = vec![1, 2, 4];
        assert_ne!(h, spec_grid_hash(&other));
        let mut other = base.clone();
        other.schedules = vec![PatternSchedule::parse("step:2:1.5").unwrap()];
        assert_ne!(h, spec_grid_hash(&other));
        let mut other = base.clone();
        other.run.tol = base.run.tol * 2.0;
        assert_ne!(h, spec_grid_hash(&other));
        // the simulation axis: no-sim vs sim, and different sim configs,
        // must all hash apart (merge refusal for tail-latency artifacts)
        let sgp_only = SweepSpec {
            algorithms: vec![Algorithm::Sgp],
            ..base.clone()
        };
        let h_plain = spec_grid_hash(&sgp_only);
        let simmed = SweepSpec {
            sim: Some(SimSweepConfig::default()),
            ..sgp_only.clone()
        };
        let h_sim = spec_grid_hash(&simmed);
        assert_ne!(h_plain, h_sim);
        let mut more = simmed.clone();
        more.sim.as_mut().unwrap().requests += 1;
        assert_ne!(h_sim, spec_grid_hash(&more));
        let mut bursty = simmed.clone();
        bursty.sim.as_mut().unwrap().arrivals = ArrivalSpec::parse("mmpp:4:1").unwrap();
        assert_ne!(h_sim, spec_grid_hash(&bursty));
        // the closed-loop validation axis: validated vs not, and different
        // tolerances, must hash apart too
        let mut validated = simmed.clone();
        validated.sim.as_mut().unwrap().validate = Some(0.25);
        let h_val = spec_grid_hash(&validated);
        assert_ne!(h_sim, h_val);
        let mut tighter = validated.clone();
        tighter.sim.as_mut().unwrap().validate = Some(0.1);
        assert_ne!(h_val, spec_grid_hash(&tighter));
        // the admission-control axis: capped vs uncapped, and different
        // caps, must hash apart (capped artifacts refuse to merge into
        // uncapped sweeps and vice versa)
        let mut capped = simmed.clone();
        capped.sim.as_mut().unwrap().queue_cap = Some(8);
        let h_cap = spec_grid_hash(&capped);
        assert_ne!(h_sim, h_cap);
        let mut tighter_cap = capped.clone();
        tighter_cap.sim.as_mut().unwrap().queue_cap = Some(4);
        assert_ne!(h_cap, spec_grid_hash(&tighter_cap));
    }

    #[test]
    fn sim_specs_reject_strategyless_algorithms_and_dynamic_schedules() {
        // lpr has no strategy to walk requests through
        let spec = SweepSpec {
            scenarios: vec!["abilene".into()],
            seeds: vec![1],
            sim: Some(SimSweepConfig::default()),
            ..SweepSpec::default()
        };
        let err = run_sweep(&spec, 1).unwrap_err().to_string();
        assert!(err.contains("lpr"), "{err}");
        // dynamic schedules re-optimize per epoch; the sweep's per-cell
        // simulation is defined for static cells only
        let spec = SweepSpec {
            scenarios: vec!["abilene".into()],
            seeds: vec![1],
            algorithms: vec![Algorithm::Sgp],
            schedules: vec![PatternSchedule::parse("step:3:1.5").unwrap()],
            sim: Some(SimSweepConfig::default()),
            ..SweepSpec::default()
        };
        let err = run_sweep(&spec, 1).unwrap_err().to_string();
        assert!(err.contains("static"), "{err}");
        // and out-of-range warm-up fractions are named
        let mut bad = SweepSpec {
            scenarios: vec!["abilene".into()],
            seeds: vec![1],
            algorithms: vec![Algorithm::Sgp],
            sim: Some(SimSweepConfig::default()),
            ..SweepSpec::default()
        };
        bad.sim.as_mut().unwrap().warmup = 1.0;
        let err = run_sweep(&bad, 1).unwrap_err().to_string();
        assert!(err.contains("warm-up"), "{err}");
    }

    #[test]
    fn simulated_cells_carry_a_tail_digest() {
        let spec = SweepSpec {
            scenarios: vec!["abilene".into()],
            seeds: vec![1],
            algorithms: vec![Algorithm::Sgp],
            sim: Some(SimSweepConfig {
                requests: 2_000,
                ..SimSweepConfig::default()
            }),
            ..SweepSpec::default()
        };
        let report = run_sweep(&spec, 1).unwrap();
        assert_eq!(report.cells.len(), 1);
        let sim = report.cells[0].sim.expect("sim-enabled cell missing digest");
        assert!(sim.p50 > 0.0 && sim.p50.is_finite());
        assert!(sim.p50 <= sim.p99 && sim.p99 <= sim.p999, "{sim:?}");
        assert!(sim.mean.is_finite());
        // spec round-trip through the shard-child flag encoding
        let args = spec_to_args(&spec);
        let k = args.iter().position(|a| a == "--sim-requests").unwrap();
        assert_eq!(args[k + 1], "2000");
        assert!(args.contains(&"--sim-arrivals".to_string()));
        assert!(args.contains(&"--sim-warmup".to_string()));
        assert!(!args.contains(&"--sim-validate".to_string()));
        assert!(!args.contains(&"--sim-queue-cap".to_string()));
        // uncapped cells carry no admission-control columns
        assert!(sim.queue_dropped.is_none() && sim.max_blocking.is_none());
    }

    #[test]
    fn capped_cells_carry_drop_columns_and_reject_zero_caps() {
        let spec = SweepSpec {
            scenarios: vec!["abilene".into()],
            seeds: vec![1],
            algorithms: vec![Algorithm::Sgp],
            sim: Some(SimSweepConfig {
                requests: 2_000,
                queue_cap: Some(1),
                ..SimSweepConfig::default()
            }),
            ..SweepSpec::default()
        };
        let report = run_sweep(&spec, 1).unwrap();
        let sim = report.cells[0].sim.expect("sim-enabled cell missing digest");
        let dropped = sim.queue_dropped.expect("capped cell missing drop column");
        let mb = sim.max_blocking.expect("capped cell missing blocking column");
        // a converged strategy at cap 1 sheds load somewhere
        assert!(dropped > 0, "{sim:?}");
        assert!((0.0..=1.0).contains(&mb) && mb > 0.0, "{sim:?}");
        // the cap survives the shard-child handoff
        let args = spec_to_args(&spec);
        let k = args.iter().position(|a| a == "--sim-queue-cap").unwrap();
        assert_eq!(args[k + 1], "1");
        // zero caps are named before any cell runs
        let mut bad = spec.clone();
        bad.sim.as_mut().unwrap().queue_cap = Some(0);
        let err = run_sweep(&bad, 1).unwrap_err().to_string();
        assert!(err.contains("sim-queue-cap"), "{err}");
    }

    #[test]
    fn validated_cells_carry_a_divergence_digest() {
        let spec = SweepSpec {
            scenarios: vec!["abilene".into()],
            seeds: vec![1],
            algorithms: vec![Algorithm::Sgp],
            sim: Some(SimSweepConfig {
                requests: 2_000,
                validate: Some(0.9),
                ..SimSweepConfig::default()
            }),
            ..SweepSpec::default()
        };
        let report = run_sweep(&spec, 1).unwrap();
        let sim = report.cells[0].sim.expect("sim-enabled cell missing digest");
        let d = sim.divergence.expect("validated cell missing divergence");
        assert!(d.mean_rel_err.is_finite() && d.mean_rel_err >= 0.0, "{d:?}");
        assert!(d.max_server_rel_err >= 0.0, "{d:?}");
        // a converged SGP cell on the stock scenario is stable, so the
        // alarm can only be a tolerance breach — impossible at tol 0.9
        // (rel_diff of two finite same-sign values is < 1)
        assert!(!d.alarm, "{d:?}");
        // the validate flag survives the shard-child handoff
        let args = spec_to_args(&spec);
        let k = args.iter().position(|a| a == "--sim-validate").unwrap();
        assert_eq!(args[k + 1], "0.9");
        // degenerate tolerances are named before any cell runs
        let mut bad = spec.clone();
        bad.sim.as_mut().unwrap().validate = Some(0.0);
        let err = run_sweep(&bad, 1).unwrap_err().to_string();
        assert!(err.contains("sim-validate"), "{err}");
    }

    #[test]
    fn unknown_scenario_names_the_cell() {
        let spec = SweepSpec {
            scenarios: vec!["no-such-scenario".into()],
            seeds: vec![1],
            algorithms: vec![Algorithm::Sgp],
            ..SweepSpec::default()
        };
        let err = run_sweep(&spec, 1).unwrap_err().to_string();
        assert!(err.contains("no-such-scenario"), "{err}");
    }

    #[test]
    fn empty_grid_rejected() {
        let spec = SweepSpec {
            scenarios: vec![],
            ..SweepSpec::default()
        };
        assert!(run_sweep(&spec, 1).is_err());
    }

    #[test]
    fn oversized_seeds_rejected_before_running() {
        let spec = SweepSpec {
            seeds: vec![(1 << 53) + 1],
            ..SweepSpec::default()
        };
        let err = run_sweep(&spec, 1).unwrap_err().to_string();
        assert!(err.contains("2^53"), "{err}");
        assert!(run_sweep_shard(&spec, 0, 2, 1).is_err());
    }

    #[test]
    fn steal_cells_run_the_exact_subset_with_global_indices() {
        let spec = SweepSpec {
            scenarios: vec!["abilene".into()],
            seeds: vec![1, 2],
            algorithms: vec![Algorithm::Lpr],
            backends: vec![CellBackend::Sparse],
            schedules: vec![PatternSchedule::static_()],
            rate_scale: 1.0,
            run: RunConfig::quick(),
            sim: None,
            cache: None,
        };
        let whole = run_sweep(&spec, 1).unwrap();
        let stolen = run_sweep_cells_with(&spec, &[1], 1, |_| {}).unwrap();
        assert_eq!(stolen.cells.len(), 1);
        assert_eq!(stolen.cells[0].index, 1);
        assert_eq!(
            stolen.cells[0].final_cost.to_bits(),
            whole.cells[1].final_cost.to_bits(),
            "a re-stolen cell must be bit-identical to its original run"
        );
        assert!(run_sweep_cells_with(&spec, &[99], 1, |_| {}).is_err());
    }

    #[test]
    fn grid_hash_tracks_the_cache_bit_but_not_the_directory() {
        let base = SweepSpec::default();
        let cached = SweepSpec {
            cache: Some("/tmp/a".into()),
            ..base.clone()
        };
        assert_ne!(
            spec_grid_hash(&base),
            spec_grid_hash(&cached),
            "cached and uncached artifacts must refuse to merge"
        );
        let elsewhere = SweepSpec {
            cache: Some("/tmp/b".into()),
            ..base.clone()
        };
        assert_eq!(
            spec_grid_hash(&cached),
            spec_grid_hash(&elsewhere),
            "the directory itself is not part of the sweep's identity"
        );
        // the shard-child handoff carries the flag
        let args = spec_to_args(&cached);
        let k = args.iter().position(|a| a == "--cache-dir").unwrap();
        assert_eq!(args[k + 1], "/tmp/a");
        assert!(!spec_to_args(&base).contains(&"--cache-dir".to_string()));
        // degenerate cache dirs are named before any cell runs
        let bad = SweepSpec {
            cache: Some(String::new()),
            ..base
        };
        let err = run_sweep(&bad, 1).unwrap_err().to_string();
        assert!(err.contains("cache-dir"), "{err}");
    }

    #[test]
    fn cached_rerun_reproduces_the_cold_fingerprint_without_solving() {
        let dir = std::env::temp_dir().join(format!(
            "cecflow-sweep-cache-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let cold_spec = SweepSpec {
            scenarios: vec!["abilene".into()],
            seeds: vec![1, 2],
            algorithms: vec![Algorithm::Sgp, Algorithm::Lpr],
            ..SweepSpec::default()
        };
        let cold = run_sweep(&cold_spec, 1).unwrap();
        let spec = SweepSpec {
            cache: Some(dir.display().to_string()),
            ..cold_spec
        };
        // first store-enabled run: everything misses, inserts, and still
        // lands on the cold fingerprint
        let first = run_sweep(&spec, 2).unwrap();
        assert_eq!(first.fingerprint(), cold.fingerprint());
        for c in &first.cells {
            match c.cell.algorithm {
                Algorithm::Sgp => {
                    let cache = c.cache.expect("sgp cell missing cache record");
                    assert!(!cache.hit);
                    assert_eq!(cache.iters_saved, 0);
                    assert!(c.phi.is_some(), "store-enabled cells ship the strategy");
                }
                _ => {
                    assert!(c.cache.is_none(), "lpr cells take no part in the store");
                    assert!(c.phi.is_none());
                }
            }
        }
        // second run: every sgp cell is a verified hit adopting the stored
        // trajectory — identical fingerprint, zero iterations executed
        let second = run_sweep(&spec, 1).unwrap();
        assert_eq!(second.fingerprint(), cold.fingerprint());
        for c in &second.cells {
            if c.cell.algorithm == Algorithm::Sgp {
                let cache = c.cache.expect("sgp cell missing cache record");
                assert!(cache.hit, "second run must hit");
                assert_eq!(cache.iters_saved, c.iterations);
                assert!(cache.iters_saved > 0);
            }
        }
        // tampering with one entry downgrades it to a miss, not a failure
        let entry = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().path())
            .find(|p| p.extension().is_some_and(|x| x == "json"))
            .unwrap();
        std::fs::write(&entry, "garbage").unwrap();
        let third = run_sweep(&spec, 1).unwrap();
        assert_eq!(third.fingerprint(), cold.fingerprint());
        let hits = third
            .cells
            .iter()
            .filter(|c| c.cache.is_some_and(|k| k.hit))
            .count();
        let misses = third
            .cells
            .iter()
            .filter(|c| c.cache.is_some_and(|k| !k.hit))
            .count();
        assert_eq!(hits, 1, "the untouched entry still hits");
        assert_eq!(misses, 1, "the corrupted entry re-runs cold");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
