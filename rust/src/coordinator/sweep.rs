//! Parallel scenario sweeps: run a grid of `scenario × seed × algorithm`
//! cells across worker threads and aggregate the outcomes into one
//! comparable report — the machinery behind the `cecflow sweep`
//! subcommand and `benches/sweep.rs`.
//!
//! Determinism is a hard contract, pinned by
//! `rust/tests/sweep_determinism.rs`: every cell derives all randomness
//! from its own `(scenario, seed)` pair (no RNG state is shared between
//! workers), and cells are written back by index, so the per-cell results
//! of a sweep are identical for any worker count — only wall-clock
//! timings vary. Workers pull cells from an atomic cursor (work
//! stealing), which keeps long cells (e.g. SW) from serializing behind a
//! static partition.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::json::Json;
use crate::util::stats::summarize;
use crate::util::table::{fnum, Table};

use super::{build_scenario_network, metrics, run_algorithm, Algorithm, RunConfig};

/// A sweep specification: the cell grid is the cross product
/// `scenarios × seeds × algorithms`, every cell run at `rate_scale` under
/// the same stopping rule.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    pub scenarios: Vec<String>,
    pub seeds: Vec<u64>,
    pub algorithms: Vec<Algorithm>,
    pub rate_scale: f64,
    pub run: RunConfig,
}

impl Default for SweepSpec {
    fn default() -> Self {
        SweepSpec {
            scenarios: vec!["abilene".to_string(), "connected-er".to_string()],
            seeds: vec![1, 2, 3],
            algorithms: vec![Algorithm::Sgp, Algorithm::Gp, Algorithm::Lpr],
            rate_scale: 1.0,
            run: RunConfig::quick(),
        }
    }
}

/// One grid cell: a scenario instance (name + seed) optimized by one
/// algorithm.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SweepCell {
    pub scenario: String,
    pub seed: u64,
    pub algorithm: Algorithm,
}

/// The outcome of one cell.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub cell: SweepCell,
    pub final_cost: f64,
    pub iterations: usize,
    pub iters_to_1pct: usize,
    pub wall_seconds: f64,
}

/// Aggregate over the seeds of one `(scenario, algorithm)` group.
#[derive(Clone, Debug)]
pub struct GroupSummary {
    pub scenario: String,
    pub algorithm: String,
    pub cells: usize,
    pub mean_cost: f64,
    pub p95_cost: f64,
    pub mean_iters_to_1pct: f64,
    pub mean_wall_seconds: f64,
}

/// A completed sweep: per-cell results in grid order plus aggregation.
#[derive(Clone, Debug)]
pub struct SweepReport {
    pub cells: Vec<CellResult>,
    pub workers: usize,
}

impl SweepSpec {
    /// The cell grid in canonical order: scenarios outermost, then seeds,
    /// then algorithms. This order is part of the determinism contract —
    /// reports compare cell-by-cell across runs and worker counts.
    pub fn cells(&self) -> Vec<SweepCell> {
        let mut out = Vec::with_capacity(
            self.scenarios.len() * self.seeds.len() * self.algorithms.len(),
        );
        for scenario in &self.scenarios {
            for &seed in &self.seeds {
                for &algorithm in &self.algorithms {
                    out.push(SweepCell {
                        scenario: scenario.clone(),
                        seed,
                        algorithm,
                    });
                }
            }
        }
        out
    }
}

fn run_cell(cell: &SweepCell, spec: &SweepSpec) -> Result<CellResult> {
    let net = build_scenario_network(&cell.scenario, cell.seed, spec.rate_scale)?;
    let start = Instant::now();
    let out = run_algorithm(&net, cell.algorithm, &spec.run)?;
    let final_cost = if out.final_cost.is_nan() {
        f64::INFINITY
    } else {
        out.final_cost
    };
    Ok(CellResult {
        cell: cell.clone(),
        final_cost,
        iterations: out.iterations,
        iters_to_1pct: metrics::iters_to_1pct(&out.costs),
        wall_seconds: start.elapsed().as_secs_f64(),
    })
}

/// Execute every cell of `spec` on up to `workers` threads (clamped to
/// `[1, #cells]`) and collect a [`SweepReport`]. Cell errors (e.g. an
/// unknown scenario name) fail the whole sweep with the offending cell
/// named.
pub fn run_sweep(spec: &SweepSpec, workers: usize) -> Result<SweepReport> {
    let cells = spec.cells();
    anyhow::ensure!(
        !cells.is_empty(),
        "empty sweep: need at least one scenario, seed and algorithm"
    );
    let workers = workers.clamp(1, cells.len());

    type CellSlot = Mutex<Option<Result<CellResult>>>;
    let next = AtomicUsize::new(0);
    // First failure stops workers from claiming further cells — a typo'd
    // scenario name should not make the user wait out the healthy cells.
    let failed = AtomicBool::new(false);
    let slots: Vec<CellSlot> = (0..cells.len()).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                if failed.load(Ordering::Relaxed) {
                    break;
                }
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= cells.len() {
                    break;
                }
                let res = run_cell(&cells[i], spec);
                if res.is_err() {
                    failed.store(true, Ordering::Relaxed);
                }
                *slots[i].lock().unwrap() = Some(res);
            });
        }
    });

    let mut results = Vec::with_capacity(cells.len());
    for (i, slot) in slots.into_iter().enumerate() {
        let res = slot.into_inner().unwrap().unwrap_or_else(|| {
            panic!(
                "sweep aborted early (cell {i} never ran) — an earlier cell's \
                 error is reported instead"
            )
        });
        results.push(res.with_context(|| {
            format!(
                "sweep cell {} ({} seed {} algo {})",
                i,
                cells[i].scenario,
                cells[i].seed,
                cells[i].algorithm.name()
            )
        })?);
    }
    Ok(SweepReport {
        cells: results,
        workers,
    })
}

impl SweepReport {
    /// Per-`(scenario, algorithm)` aggregates in first-appearance order.
    pub fn groups(&self) -> Vec<GroupSummary> {
        let mut order: Vec<(String, String)> = Vec::new();
        let mut buckets: Vec<Vec<&CellResult>> = Vec::new();
        for cell in &self.cells {
            let key = (
                cell.cell.scenario.clone(),
                cell.cell.algorithm.name().to_string(),
            );
            match order.iter().position(|k| *k == key) {
                Some(i) => buckets[i].push(cell),
                None => {
                    order.push(key);
                    buckets.push(vec![cell]);
                }
            }
        }
        order
            .into_iter()
            .zip(buckets)
            .map(|((scenario, algorithm), cells)| {
                let costs: Vec<f64> = cells.iter().map(|c| c.final_cost).collect();
                let s = summarize(&costs);
                let n = cells.len() as f64;
                GroupSummary {
                    scenario,
                    algorithm,
                    cells: cells.len(),
                    mean_cost: s.mean,
                    p95_cost: s.p95,
                    mean_iters_to_1pct: cells
                        .iter()
                        .map(|c| c.iters_to_1pct as f64)
                        .sum::<f64>()
                        / n,
                    mean_wall_seconds: cells.iter().map(|c| c.wall_seconds).sum::<f64>() / n,
                }
            })
            .collect()
    }

    /// Deterministic identity of the sweep's results: everything except
    /// wall-clock timing, with costs compared bit-for-bit. Two sweeps of
    /// the same spec must produce equal fingerprints regardless of worker
    /// count (`rust/tests/sweep_determinism.rs`).
    pub fn fingerprint(&self) -> Vec<(String, u64, String, u64, usize, usize)> {
        self.cells
            .iter()
            .map(|c| {
                (
                    c.cell.scenario.clone(),
                    c.cell.seed,
                    c.cell.algorithm.name().to_string(),
                    c.final_cost.to_bits(),
                    c.iterations,
                    c.iters_to_1pct,
                )
            })
            .collect()
    }

    /// Paper-style text table of the group aggregates.
    pub fn render(&self) -> String {
        let mut t = Table::new(&[
            "scenario",
            "algo",
            "cells",
            "mean T",
            "p95 T",
            "iters->1%",
            "mean wall s",
        ]);
        for g in self.groups() {
            t.row(vec![
                g.scenario,
                g.algorithm,
                g.cells.to_string(),
                fnum(g.mean_cost),
                fnum(g.p95_cost),
                format!("{:.1}", g.mean_iters_to_1pct),
                format!("{:.3}", g.mean_wall_seconds),
            ]);
        }
        t.render()
    }

    /// Machine-readable report (cells + groups).
    pub fn to_json(&self) -> Json {
        let cells: Vec<Json> = self
            .cells
            .iter()
            .map(|c| {
                let mut o = Json::obj();
                o.set("scenario", Json::Str(c.cell.scenario.clone()))
                    .set("seed", Json::Num(c.cell.seed as f64))
                    .set(
                        "algorithm",
                        Json::Str(c.cell.algorithm.name().to_string()),
                    )
                    .set("final_cost", Json::Num(c.final_cost))
                    .set("iterations", Json::Num(c.iterations as f64))
                    .set("iters_to_1pct", Json::Num(c.iters_to_1pct as f64))
                    .set("wall_seconds", Json::Num(c.wall_seconds));
                o
            })
            .collect();
        let groups: Vec<Json> = self
            .groups()
            .into_iter()
            .map(|g| {
                let mut o = Json::obj();
                o.set("scenario", Json::Str(g.scenario))
                    .set("algorithm", Json::Str(g.algorithm))
                    .set("cells", Json::Num(g.cells as f64))
                    .set("mean_cost", Json::Num(g.mean_cost))
                    .set("p95_cost", Json::Num(g.p95_cost))
                    .set("mean_iters_to_1pct", Json::Num(g.mean_iters_to_1pct))
                    .set("mean_wall_seconds", Json::Num(g.mean_wall_seconds));
                o
            })
            .collect();
        let mut doc = Json::obj();
        doc.set("workers", Json::Num(self.workers as f64))
            .set("cells", Json::Arr(cells))
            .set("groups", Json::Arr(groups));
        doc
    }
}

/// Parse a comma-separated scenario list (`"abilene,connected-er"`).
pub fn parse_scenarios(s: &str) -> Vec<String> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(str::to_string)
        .collect()
}

/// Largest seed accepted from the CLI: seeds are reported in JSON, whose
/// numbers are f64, so anything above 2^53 would silently collide with a
/// neighbor in `sweep.json`.
const MAX_SEED: u64 = 1 << 53;

/// Parse a comma-separated seed list (`"1,2,3"`) or an inclusive range
/// (`"1..8"`). Seeds above 2^53 are rejected (not representable in the
/// JSON report).
pub fn parse_seeds(s: &str) -> Result<Vec<u64>> {
    let check = |seed: u64| -> Result<u64> {
        anyhow::ensure!(
            seed <= MAX_SEED,
            "seed {seed} exceeds 2^53 and would lose precision in the JSON report"
        );
        Ok(seed)
    };
    if let Some((lo, hi)) = s.split_once("..") {
        let lo: u64 = lo.trim().parse().context("seed range start")?;
        let hi: u64 = check(hi.trim().parse().context("seed range end")?)?;
        anyhow::ensure!(lo <= hi, "empty seed range {lo}..{hi}");
        return Ok((lo..=hi).collect());
    }
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| {
            t.parse::<u64>()
                .with_context(|| format!("bad seed '{t}'"))
                .and_then(check)
        })
        .collect()
}

/// Parse a comma-separated algorithm list (`"sgp,gp,lpr"`).
pub fn parse_algorithms(s: &str) -> Result<Vec<Algorithm>> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| Algorithm::parse(t).with_context(|| format!("unknown algorithm '{t}'")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_grid_order_is_canonical() {
        let spec = SweepSpec {
            scenarios: vec!["a".into(), "b".into()],
            seeds: vec![1, 2],
            algorithms: vec![Algorithm::Sgp, Algorithm::Lpr],
            rate_scale: 1.0,
            run: RunConfig::quick(),
        };
        let cells = spec.cells();
        assert_eq!(cells.len(), 8);
        assert_eq!(cells[0].scenario, "a");
        assert_eq!(cells[0].seed, 1);
        assert_eq!(cells[0].algorithm, Algorithm::Sgp);
        assert_eq!(cells[1].algorithm, Algorithm::Lpr);
        assert_eq!(cells[2].seed, 2);
        assert_eq!(cells[4].scenario, "b");
    }

    #[test]
    fn sweep_runs_and_aggregates() {
        let spec = SweepSpec {
            scenarios: vec!["abilene".into()],
            seeds: vec![1, 2],
            algorithms: vec![Algorithm::Sgp, Algorithm::Lpr],
            rate_scale: 1.0,
            run: RunConfig::quick(),
        };
        let report = run_sweep(&spec, 2).unwrap();
        assert_eq!(report.cells.len(), 4);
        let groups = report.groups();
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0].algorithm, "sgp");
        assert_eq!(groups[0].cells, 2);
        assert!(groups[0].mean_cost.is_finite());
        // Fig. 4 headline on the means: SGP at or below LPR (same relative
        // tolerance as the fig4 bench's shape check)
        assert!(groups[0].mean_cost <= groups[1].mean_cost * 1.001);
        let txt = report.render();
        assert!(txt.contains("abilene"));
        assert!(txt.contains("sgp"));
        let doc = report.to_json();
        assert_eq!(doc.get("cells").as_arr().unwrap().len(), 4);
    }

    #[test]
    fn unknown_scenario_names_the_cell() {
        let spec = SweepSpec {
            scenarios: vec!["no-such-scenario".into()],
            seeds: vec![1],
            algorithms: vec![Algorithm::Sgp],
            rate_scale: 1.0,
            run: RunConfig::quick(),
        };
        let err = run_sweep(&spec, 1).unwrap_err().to_string();
        assert!(err.contains("no-such-scenario"), "{err}");
    }

    #[test]
    fn empty_grid_rejected() {
        let spec = SweepSpec {
            scenarios: vec![],
            ..SweepSpec::default()
        };
        assert!(run_sweep(&spec, 1).is_err());
    }

    #[test]
    fn list_parsers() {
        assert_eq!(parse_scenarios("a, b,"), vec!["a", "b"]);
        assert_eq!(parse_seeds("1, 2,3").unwrap(), vec![1, 2, 3]);
        assert_eq!(parse_seeds("4..6").unwrap(), vec![4, 5, 6]);
        assert!(parse_seeds("9..2").is_err());
        assert!(parse_seeds("x").is_err());
        // seeds past 2^53 would alias in the f64-backed JSON report
        assert!(parse_seeds("9007199254740993").is_err());
        assert_eq!(
            parse_algorithms("sgp,lpr").unwrap(),
            vec![Algorithm::Sgp, Algorithm::Lpr]
        );
        assert!(parse_algorithms("sgp,zzz").is_err());
    }
}
