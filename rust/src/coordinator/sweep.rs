//! Parallel and process-sharded scenario sweeps: run a grid of
//! `scenario × seed × algorithm × backend × schedule` cells across worker
//! threads — and, with `cecflow sweep --shards N` / `--shard i/n`, across
//! child *processes* — then aggregate the outcomes into one comparable
//! report. This is the machinery behind the `cecflow sweep` subcommand
//! and `benches/sweep.rs`. Cells with a non-static
//! [`PatternSchedule`] run the dynamic task-pattern engine
//! ([`super::dynamics`]) warm-started, and additionally record their
//! per-epoch final costs.
//!
//! Determinism is a hard contract, pinned by
//! `rust/tests/sweep_determinism.rs` and `rust/tests/sweep_shard.rs`:
//! every cell derives all randomness from its own `(scenario, seed)` pair
//! (no RNG state is shared between workers), and results carry their
//! global grid index, so the per-cell results of a sweep are identical for
//! any worker count *and* any shard count — only wall-clock timings vary.
//! Workers pull cells from an atomic cursor (work stealing), which keeps
//! long cells (e.g. SW) from serializing behind a static partition.
//!
//! ## Process sharding
//!
//! A sharded sweep splits the cell grid over `n` `cecflow` child
//! processes. Shard `k` (1-based on the CLI) owns the strided index set
//! `{k-1, k-1+n, k-1+2n, …}` — striding balances expensive scenarios
//! (grid order keeps one scenario's cells adjacent) across shards. Each
//! child runs `cecflow sweep --shard-worker k/n` with the same spec flags
//! and speaks a JSON-lines protocol on stdout: one `{"type":"cell",…}`
//! object per finished cell (carrying the global index and the exact cost
//! bits), a final `{"type":"done",…}`, or `{"type":"error",…}` on
//! failure. The parent reassembles the slots by index, so the merged
//! [`SweepReport`] fingerprint is identical to a single-process run of
//! the same spec. Shard reports written with `--shard i/n --out f.json`
//! are first-class artifacts: [`SweepReport::from_json`] +
//! [`SweepReport::merge`] (CLI: `cecflow sweep --merge a.json,b.json`)
//! reassemble them across hosts.

use std::io::BufRead;
use std::panic::AssertUnwindSafe;
use std::path::Path;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;
use crate::util::stats::summarize;
use crate::util::table::{fnum, Table};

use super::dynamics::{AdaptiveRunner, PatternSchedule};
use super::{
    build_scenario_network, metrics, run_algorithm_with_backend, Algorithm, CellBackend,
    RunConfig,
};

/// A sweep specification: the cell grid is the cross product
/// `scenarios × seeds × algorithms × backends × schedules` (non-SGP
/// algorithms only pair with [`CellBackend::Sparse`] — they have no dense
/// path — and non-static schedules only pair with the iterative
/// [`Algorithm::supports_dynamic`] algorithms), every cell run at
/// `rate_scale` under the same stopping rule.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    pub scenarios: Vec<String>,
    pub seeds: Vec<u64>,
    pub algorithms: Vec<Algorithm>,
    /// Dense-evaluation routes to sweep SGP cells over. `[Sparse]` (the
    /// default) reproduces the pre-routing grid exactly.
    pub backends: Vec<CellBackend>,
    /// Task-pattern schedules to sweep over. `[static]` (the default)
    /// reproduces the pre-dynamics grid exactly; other entries run the
    /// warm-started dynamic engine and report the last epoch's cost.
    pub schedules: Vec<PatternSchedule>,
    pub rate_scale: f64,
    pub run: RunConfig,
}

impl Default for SweepSpec {
    fn default() -> Self {
        SweepSpec {
            scenarios: vec!["abilene".to_string(), "connected-er".to_string()],
            seeds: vec![1, 2, 3],
            algorithms: vec![Algorithm::Sgp, Algorithm::Gp, Algorithm::Lpr],
            backends: vec![CellBackend::Sparse],
            schedules: vec![PatternSchedule::static_()],
            rate_scale: 1.0,
            run: RunConfig::quick(),
        }
    }
}

/// One grid cell: a scenario instance (name + seed) optimized by one
/// algorithm through one dense-evaluation route, under one task-pattern
/// schedule.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SweepCell {
    pub scenario: String,
    pub seed: u64,
    pub algorithm: Algorithm,
    pub backend: CellBackend,
    pub schedule: PatternSchedule,
}

/// The outcome of one cell, tagged with its global grid index so shard
/// outputs can be reassembled in canonical order.
#[derive(Clone, Debug)]
pub struct CellResult {
    /// Position of this cell in [`SweepSpec::cells`] order.
    pub index: usize,
    pub cell: SweepCell,
    pub final_cost: f64,
    pub iterations: usize,
    pub iters_to_1pct: usize,
    pub wall_seconds: f64,
    /// Per-epoch final costs of a dynamic (non-static-schedule) cell, in
    /// epoch order; empty for static cells. Carried bit-exactly through
    /// the shard protocol and report artifacts, and part of the
    /// fingerprint — per-epoch results must be identical across worker
    /// and shard counts.
    pub epoch_costs: Vec<f64>,
}

/// Aggregate over the seeds of one
/// `(scenario, algorithm, backend, schedule)` group.
#[derive(Clone, Debug)]
pub struct GroupSummary {
    pub scenario: String,
    pub algorithm: String,
    pub backend: String,
    pub schedule: String,
    pub cells: usize,
    pub mean_cost: f64,
    pub p95_cost: f64,
    pub mean_iters_to_1pct: f64,
    pub mean_wall_seconds: f64,
}

/// A completed sweep: per-cell results in grid order plus aggregation.
#[derive(Clone, Debug)]
pub struct SweepReport {
    pub cells: Vec<CellResult>,
    /// Worker threads used (total budget for sharded runs). Metadata only
    /// — like wall times, excluded from [`SweepReport::fingerprint`].
    pub workers: usize,
    /// Identity of the generating spec ([`spec_grid_hash`]); `0` when
    /// unknown (hand-built reports). [`SweepReport::merge`] refuses to
    /// combine shard reports whose nonzero hashes differ — index coverage
    /// alone cannot tell two same-sized grids apart.
    pub grid_hash: u64,
}

impl SweepSpec {
    /// The cell grid in canonical order: scenarios outermost, then seeds,
    /// then algorithms, then backends, then schedules. This order is part
    /// of the determinism contract — reports compare cell-by-cell across
    /// runs, worker counts and shard counts. Non-SGP × non-`Sparse`
    /// combinations are skipped (no dense path exists for the baselines),
    /// as are non-static schedules on algorithms without a dynamic path
    /// ([`Algorithm::supports_dynamic`]).
    pub fn cells(&self) -> Vec<SweepCell> {
        let mut out = Vec::with_capacity(
            self.scenarios.len()
                * self.seeds.len()
                * self.algorithms.len()
                * self.backends.len()
                * self.schedules.len(),
        );
        for scenario in &self.scenarios {
            for &seed in &self.seeds {
                for &algorithm in &self.algorithms {
                    for &backend in &self.backends {
                        if backend != CellBackend::Sparse && algorithm != Algorithm::Sgp {
                            continue;
                        }
                        for &schedule in &self.schedules {
                            if !schedule.is_static() && !algorithm.supports_dynamic() {
                                continue;
                            }
                            out.push(SweepCell {
                                scenario: scenario.clone(),
                                seed,
                                algorithm,
                                backend,
                                schedule,
                            });
                        }
                    }
                }
            }
        }
        out
    }
}

fn run_cell(index: usize, cell: &SweepCell, spec: &SweepSpec) -> Result<CellResult> {
    if !cell.schedule.is_static() {
        return run_dynamic_cell(index, cell, spec);
    }
    let net = build_scenario_network(&cell.scenario, cell.seed, spec.rate_scale)?;
    let start = Instant::now();
    let out = run_algorithm_with_backend(&net, cell.algorithm, cell.backend, &spec.run)?;
    let final_cost = if out.final_cost.is_nan() {
        f64::INFINITY
    } else {
        out.final_cost
    };
    Ok(CellResult {
        index,
        cell: cell.clone(),
        final_cost,
        iterations: out.iterations,
        iters_to_1pct: metrics::iters_to_1pct(&out.costs),
        wall_seconds: start.elapsed().as_secs_f64(),
        epoch_costs: Vec::new(),
    })
}

/// A dynamic (non-static-schedule) cell: the warm-started adaptive run
/// over the cell's schedule. The reported cost is the *last* epoch's
/// converged cost, iterations count the whole run, iters-to-1% is the
/// **sum of the per-epoch counts** (each epoch measured against its own
/// converged cost — an index into a concatenated trajectory would
/// straddle epoch boundaries and measure nothing), and the per-epoch
/// finals ride along in [`CellResult::epoch_costs`].
fn run_dynamic_cell(index: usize, cell: &SweepCell, spec: &SweepSpec) -> Result<CellResult> {
    let start = Instant::now();
    let runner = AdaptiveRunner {
        algorithm: cell.algorithm,
        backend: cell.backend,
        warm: true,
        run: spec.run,
    };
    let trace = runner.run_scenario(&cell.scenario, cell.seed, spec.rate_scale, cell.schedule)?;
    let sanitize = |x: f64| if x.is_nan() { f64::INFINITY } else { x };
    let last = trace.epochs.last().expect("a schedule has at least 1 epoch");
    Ok(CellResult {
        index,
        cell: cell.clone(),
        final_cost: sanitize(last.final_cost),
        iterations: trace.epochs.iter().map(|e| e.iterations).sum(),
        iters_to_1pct: trace.epochs.iter().map(|e| e.iters_to_1pct).sum(),
        wall_seconds: start.elapsed().as_secs_f64(),
        epoch_costs: trace.epochs.iter().map(|e| sanitize(e.final_cost)).collect(),
    })
}

/// Deterministic identity of a sweep spec's result-relevant content:
/// FNV-1a over the full cell grid plus the rate scale and stopping rule.
/// Stamped into every report this module produces so [`SweepReport::merge`]
/// can refuse shard artifacts that come from different sweeps.
pub fn spec_grid_hash(spec: &SweepSpec) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    };
    for cell in spec.cells() {
        eat(cell.scenario.as_bytes());
        eat(&[0]);
        eat(&cell.seed.to_le_bytes());
        eat(cell.algorithm.name().as_bytes());
        eat(&[0]);
        eat(cell.backend.name().as_bytes());
        eat(&[0]);
        // the schedule axis is identity-relevant: shard artifacts from
        // different schedule grids must never merge silently
        eat(cell.schedule.label().as_bytes());
        eat(&[0xff]);
    }
    eat(&spec.rate_scale.to_bits().to_le_bytes());
    eat(&(spec.run.max_iters as u64).to_le_bytes());
    eat(&spec.run.tol.to_bits().to_le_bytes());
    eat(&(spec.run.patience as u64).to_le_bytes());
    h
}

/// Reject specs whose cells cannot round-trip through the JSON shard
/// protocol / report artifacts (seeds above 2^53 lose precision as f64).
/// The CLI seed parser enforces this too; this guard covers library users.
fn validate_spec(spec: &SweepSpec) -> Result<()> {
    for &seed in &spec.seeds {
        anyhow::ensure!(
            seed <= MAX_SEED,
            "seed {seed} exceeds 2^53 and cannot round-trip through the sweep's JSON \
             protocol/artifacts"
        );
    }
    Ok(())
}

/// Human-readable cell identity used in error contexts.
fn describe_cell(index: usize, cell: &SweepCell) -> String {
    format!(
        "sweep cell {index} ({} seed {} algo {} backend {} schedule {})",
        cell.scenario,
        cell.seed,
        cell.algorithm.name(),
        cell.backend.name(),
        cell.schedule.label()
    )
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// The worker pool shared by every sweep entry point: run `cells` (global
/// index + cell) on up to `workers` threads, calling `on_cell` as each
/// cell finishes (the `--shard-worker` streaming hook).
///
/// Failure discipline: the first failing cell raises a flag that stops
/// workers from *claiming* further cells (a typo'd scenario name must not
/// make the user wait out the healthy cells), and the whole sweep returns
/// that cell's error with the cell named. A **panicking** cell cannot
/// deadlock or poison the pool: the panic is caught at the cell boundary
/// and surfaced as that cell's error (so `std::thread::scope` joins
/// normally), and slot mutexes are read through `PoisonError::into_inner`
/// so even a poisoned lock yields its data.
fn run_cells_with<F>(
    cells: &[(usize, SweepCell)],
    workers: usize,
    runner: F,
    on_cell: Option<&(dyn Fn(&CellResult) + Sync)>,
) -> Result<Vec<CellResult>>
where
    F: Fn(usize, &SweepCell) -> Result<CellResult> + Sync,
{
    anyhow::ensure!(
        !cells.is_empty(),
        "empty sweep: need at least one scenario, seed and algorithm"
    );
    let workers = workers.clamp(1, cells.len());

    type CellSlot = Mutex<Option<Result<CellResult>>>;
    let next = AtomicUsize::new(0);
    let failed = AtomicBool::new(false);
    let slots: Vec<CellSlot> = (0..cells.len()).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                if failed.load(Ordering::Relaxed) {
                    break;
                }
                let k = next.fetch_add(1, Ordering::Relaxed);
                if k >= cells.len() {
                    break;
                }
                let (index, cell) = &cells[k];
                let res = std::panic::catch_unwind(AssertUnwindSafe(|| runner(*index, cell)))
                    .unwrap_or_else(|payload| {
                        Err(anyhow::anyhow!(
                            "cell panicked: {}",
                            panic_message(payload.as_ref())
                        ))
                    });
                match &res {
                    Ok(cr) => {
                        if let Some(cb) = on_cell {
                            cb(cr);
                        }
                    }
                    Err(_) => failed.store(true, Ordering::Relaxed),
                }
                *slots[k].lock().unwrap_or_else(|p| p.into_inner()) = Some(res);
            });
        }
    });

    // The cursor hands out cells in order, so unclaimed (None) slots can
    // only sit *after* every claimed one — the first error is always
    // reached before any cancellation gap.
    let mut out = Vec::with_capacity(cells.len());
    let mut skipped: Option<usize> = None;
    for (k, slot) in slots.into_iter().enumerate() {
        match slot.into_inner().unwrap_or_else(|p| p.into_inner()) {
            Some(res) => {
                out.push(res.with_context(|| describe_cell(cells[k].0, &cells[k].1))?)
            }
            None => skipped = skipped.or(Some(k)),
        }
    }
    if let Some(k) = skipped {
        bail!(
            "sweep aborted early ({} never ran) without a reported error",
            describe_cell(cells[k].0, &cells[k].1)
        );
    }
    Ok(out)
}

/// Execute every cell of `spec` on up to `workers` threads (clamped to
/// `[1, #cells]`) and collect a [`SweepReport`]. Cell errors (e.g. an
/// unknown scenario name) fail the whole sweep with the offending cell
/// named.
pub fn run_sweep(spec: &SweepSpec, workers: usize) -> Result<SweepReport> {
    validate_spec(spec)?;
    let cells: Vec<(usize, SweepCell)> = spec.cells().into_iter().enumerate().collect();
    let results = run_cells_with(&cells, workers, |i, c| run_cell(i, c, spec), None)?;
    Ok(SweepReport {
        cells: results,
        workers: workers.clamp(1, cells.len().max(1)),
        grid_hash: spec_grid_hash(spec),
    })
}

/// Global cell indices owned by shard `shard` (0-based) of `count`: the
/// strided set `{shard, shard+count, shard+2·count, …}`.
pub fn shard_cell_indices(total: usize, shard: usize, count: usize) -> Vec<usize> {
    (shard..total).step_by(count.max(1)).collect()
}

/// Run one shard of `spec` in-process: the cells of
/// [`shard_cell_indices`], with `shard` 0-based. The report's cells carry
/// their *global* grid indices, so shard reports merge back into the
/// single-process report via [`SweepReport::merge`].
pub fn run_sweep_shard(
    spec: &SweepSpec,
    shard: usize,
    count: usize,
    workers: usize,
) -> Result<SweepReport> {
    run_sweep_shard_with(spec, shard, count, workers, |_| {})
}

/// [`run_sweep_shard`] with a completion hook: `on_cell` is called (from
/// worker threads) as each cell finishes — the `--shard-worker` mode
/// streams protocol lines through it.
pub fn run_sweep_shard_with<F>(
    spec: &SweepSpec,
    shard: usize,
    count: usize,
    workers: usize,
    on_cell: F,
) -> Result<SweepReport>
where
    F: Fn(&CellResult) + Sync,
{
    anyhow::ensure!(
        count >= 1 && shard < count,
        "shard index {shard} out of range for {count} shard(s)"
    );
    validate_spec(spec)?;
    let all = spec.cells();
    anyhow::ensure!(
        !all.is_empty(),
        "empty sweep: need at least one scenario, seed and algorithm"
    );
    let mine: Vec<(usize, SweepCell)> = shard_cell_indices(all.len(), shard, count)
        .into_iter()
        .map(|i| (i, all[i].clone()))
        .collect();
    if mine.is_empty() {
        // more shards than cells: this shard legitimately owns nothing
        return Ok(SweepReport {
            cells: Vec::new(),
            workers: 0,
            grid_hash: spec_grid_hash(spec),
        });
    }
    let results = run_cells_with(&mine, workers, |i, c| run_cell(i, c, spec), Some(&on_cell))?;
    Ok(SweepReport {
        cells: results,
        workers: workers.clamp(1, mine.len()),
        grid_hash: spec_grid_hash(spec),
    })
}

// ---------------------------------------------------------------------------
// JSON-lines shard protocol (`--shard-worker` stdout)
// ---------------------------------------------------------------------------

/// One parsed line of the `--shard-worker` stdout protocol.
#[derive(Clone, Debug)]
pub enum ShardLine {
    /// A finished cell (global index inside).
    Cell(CellResult),
    /// Shard finished cleanly after reporting `cells` results.
    Done { shard: usize, cells: usize },
    /// Shard failed; the parent surfaces `message` as its error.
    Error { message: String },
}

/// Serialize a finished cell as one protocol line (compact JSON, no
/// newline). The cost travels as exact bits (`final_cost_bits`), so the
/// parent's merged report is bit-identical to an in-process run.
pub fn cell_line(cell: &CellResult) -> String {
    let mut o = cell.to_json();
    o.set("type", Json::Str("cell".to_string()));
    o.dump()
}

/// Serialize the shard-completed protocol line (`shard` 0-based).
pub fn done_line(shard: usize, cells: usize) -> String {
    let mut o = Json::obj();
    o.set("type", Json::Str("done".to_string()))
        .set("shard", Json::Num(shard as f64))
        .set("cells", Json::Num(cells as f64));
    o.dump()
}

/// Serialize the shard-failed protocol line.
pub fn error_line(message: &str) -> String {
    let mut o = Json::obj();
    o.set("type", Json::Str("error".to_string()))
        .set("message", Json::Str(message.to_string()));
    o.dump()
}

/// Parse one protocol line.
pub fn parse_shard_line(line: &str) -> Result<ShardLine> {
    let doc = Json::parse(line).with_context(|| format!("bad shard protocol line: {line}"))?;
    match doc.get("type").as_str() {
        Some("cell") => Ok(ShardLine::Cell(CellResult::from_json(&doc)?)),
        Some("done") => Ok(ShardLine::Done {
            shard: doc.get("shard").as_usize().unwrap_or(0),
            cells: doc.get("cells").as_usize().unwrap_or(0),
        }),
        Some("error") => Ok(ShardLine::Error {
            message: doc
                .get("message")
                .as_str()
                .unwrap_or("unknown shard error")
                .to_string(),
        }),
        other => bail!("unknown shard protocol line type {other:?} in: {line}"),
    }
}

/// Parse a `--shard i/n` / `--shard-worker i/n` argument (`i` 1-based on
/// the CLI). Returns the 0-based shard index and the shard count.
pub fn parse_shard_arg(s: &str) -> Result<(usize, usize)> {
    let (i, n) = s
        .split_once('/')
        .with_context(|| format!("--shard expects i/n (e.g. 1/4), got '{s}'"))?;
    let i: usize = i
        .trim()
        .parse()
        .with_context(|| format!("bad shard index '{i}'"))?;
    let n: usize = n
        .trim()
        .parse()
        .with_context(|| format!("bad shard count '{n}'"))?;
    anyhow::ensure!(n >= 1, "shard count must be at least 1");
    anyhow::ensure!((1..=n).contains(&i), "shard index {i} out of range 1..={n}");
    Ok((i - 1, n))
}

/// Reconstruct the `cecflow sweep` CLI flags describing `spec` — the
/// parent → child handoff of the process-sharded sweep. Every field that
/// affects cell results is encoded, so a child parsing these flags
/// rebuilds an identical grid and stopping rule.
pub fn spec_to_args(spec: &SweepSpec) -> Vec<String> {
    let join = |parts: Vec<String>| parts.join(",");
    vec![
        "--scenarios".to_string(),
        spec.scenarios.join(","),
        "--seeds".to_string(),
        join(spec.seeds.iter().map(u64::to_string).collect()),
        "--algos".to_string(),
        join(spec.algorithms.iter().map(|a| a.name().to_string()).collect()),
        "--backends".to_string(),
        join(spec.backends.iter().map(|b| b.name().to_string()).collect()),
        "--schedules".to_string(),
        join(spec.schedules.iter().map(|s| s.label()).collect()),
        // f64 Display is the shortest round-tripping decimal, so the
        // child parses back the exact same value
        "--scale".to_string(),
        spec.rate_scale.to_string(),
        "--iters".to_string(),
        spec.run.max_iters.to_string(),
        "--tol".to_string(),
        spec.run.tol.to_string(),
        "--patience".to_string(),
        spec.run.patience.to_string(),
    ]
}

// ---------------------------------------------------------------------------
// Process-sharded orchestration (parent side)
// ---------------------------------------------------------------------------

/// Options for [`run_sweep_sharded`].
#[derive(Clone, Debug)]
pub struct ShardOptions {
    /// Number of child processes (clamped to `[1, #cells]`).
    pub shards: usize,
    /// Total worker-thread budget, divided evenly across children.
    pub workers: usize,
    /// Overall deadline for the whole sharded run; `None` waits forever.
    /// On expiry every child is killed and the error names the first cell
    /// still outstanding.
    pub timeout: Option<Duration>,
}

fn kill_children(children: &mut [Child]) {
    for c in children.iter_mut() {
        let _ = c.kill();
        let _ = c.wait();
    }
}

/// Wait for one child, bounded by the sharded sweep's overall deadline:
/// past the deadline the child is killed and an error returned, so
/// [`ShardOptions::timeout`] holds even for a child that wedges *after*
/// closing its stdout (the protocol loop can no longer observe it).
fn wait_with_deadline(
    child: &mut Child,
    deadline: Option<Instant>,
) -> Result<std::process::ExitStatus> {
    loop {
        if let Some(status) = child.try_wait().context("polling child status")? {
            return Ok(status);
        }
        if deadline.is_some_and(|d| Instant::now() >= d) {
            let _ = child.kill();
            let _ = child.wait();
            bail!("child did not exit before the sweep deadline");
        }
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Run `spec` sharded across `opts.shards` child processes of the
/// `cecflow` binary at `exe` (the CLI passes `std::env::current_exe()`;
/// tests pass `env!("CARGO_BIN_EXE_cecflow")`).
///
/// The parent partitions cells by [`shard_cell_indices`], spawns one
/// `sweep --shard-worker k/n` child per shard (JSON-lines results over
/// stdout, human chatter on inherited stderr), and reassembles the
/// results by global index. Child failure, protocol corruption, nonzero
/// exit and timeout all kill the remaining children and return a
/// contextful error naming the shard and, where known, the cell.
///
/// Pinned by `rust/tests/sweep_shard.rs`: the merged report's
/// [`SweepReport::fingerprint`] equals the single-process
/// [`run_sweep`] fingerprint on the same spec.
pub fn run_sweep_sharded(spec: &SweepSpec, exe: &Path, opts: &ShardOptions) -> Result<SweepReport> {
    validate_spec(spec)?;
    let cells = spec.cells();
    anyhow::ensure!(
        !cells.is_empty(),
        "empty sweep: need at least one scenario, seed and algorithm"
    );
    let shards = opts.shards.clamp(1, cells.len());
    let child_workers = (opts.workers / shards).max(1);

    enum Event {
        Line(usize, String),
        ReadError(usize, String),
        Eof(usize),
    }

    let (tx, rx) = mpsc::channel::<Event>();
    let mut children: Vec<Child> = Vec::with_capacity(shards);
    for shard in 0..shards {
        let mut cmd = Command::new(exe);
        cmd.arg("sweep")
            .args(spec_to_args(spec))
            .arg("--shard-worker")
            .arg(format!("{}/{shards}", shard + 1))
            .arg("--workers")
            .arg(child_workers.to_string())
            .stdin(Stdio::null())
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit());
        let mut child = cmd.spawn().with_context(|| {
            format!(
                "spawning sweep shard {}/{shards} ({})",
                shard + 1,
                exe.display()
            )
        })?;
        let stdout = child.stdout.take().expect("stdout was piped");
        let tx = tx.clone();
        std::thread::spawn(move || {
            for line in std::io::BufReader::new(stdout).lines() {
                match line {
                    Ok(l) => {
                        if tx.send(Event::Line(shard, l)).is_err() {
                            return;
                        }
                    }
                    Err(e) => {
                        let _ = tx.send(Event::ReadError(shard, e.to_string()));
                        return;
                    }
                }
            }
            let _ = tx.send(Event::Eof(shard));
        });
        children.push(child);
    }
    drop(tx);

    let deadline = opts.timeout.map(|t| Instant::now() + t);
    let mut slots: Vec<Option<CellResult>> = vec![None; cells.len()];
    let mut eofs = 0usize;
    // which shards sent their `done` line — an EOF without it means the
    // child died abnormally (OOM-kill, panic before the protocol started)
    let mut done = vec![false; shards];
    while eofs < shards {
        let timed_out = |slots: &[Option<CellResult>], children: &mut [Child]| {
            let missing = slots.iter().position(|s| s.is_none());
            kill_children(children);
            let what = missing
                .map(|i| {
                    format!(
                        " waiting for {} (shard {}/{shards})",
                        describe_cell(i, &cells[i]),
                        i % shards + 1
                    )
                })
                .unwrap_or_default();
            anyhow::anyhow!(
                "sharded sweep timed out after {:.1}s{what}",
                opts.timeout.unwrap_or_default().as_secs_f64()
            )
        };
        let ev = if let Some(d) = deadline {
            match d.checked_duration_since(Instant::now()) {
                None => return Err(timed_out(&slots, &mut children)),
                Some(left) => match rx.recv_timeout(left) {
                    Ok(ev) => ev,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        return Err(timed_out(&slots, &mut children))
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                },
            }
        } else {
            match rx.recv() {
                Ok(ev) => ev,
                Err(_) => break,
            }
        };
        match ev {
            Event::Line(shard, line) => {
                if line.trim().is_empty() {
                    continue;
                }
                let parsed = match parse_shard_line(&line) {
                    Ok(p) => p,
                    Err(e) => {
                        kill_children(&mut children);
                        return Err(e.context(format!(
                            "sweep shard {}/{shards} spoke garbage on stdout",
                            shard + 1
                        )));
                    }
                };
                match parsed {
                    ShardLine::Cell(c) => {
                        let i = c.index;
                        if i >= cells.len() || cells[i] != c.cell {
                            kill_children(&mut children);
                            bail!(
                                "sweep shard {}/{shards} reported a result for a cell not in \
                                 this grid (index {i})",
                                shard + 1
                            );
                        }
                        if slots[i].is_some() {
                            kill_children(&mut children);
                            bail!(
                                "sweep shard {}/{shards} reported {} twice",
                                shard + 1,
                                describe_cell(i, &cells[i])
                            );
                        }
                        slots[i] = Some(c);
                    }
                    ShardLine::Error { message } => {
                        kill_children(&mut children);
                        bail!("sweep shard {}/{shards} failed: {message}", shard + 1);
                    }
                    ShardLine::Done { .. } => done[shard] = true,
                }
            }
            Event::ReadError(shard, msg) => {
                kill_children(&mut children);
                bail!(
                    "reading results from sweep shard {}/{shards}: {msg}",
                    shard + 1
                );
            }
            Event::Eof(shard) => {
                eofs += 1;
                // Fail fast on abnormal child death: stdout closed without
                // a `done` (or `error`) line. Don't let the healthy shards
                // run out the clock producing a result that must be thrown
                // away anyway.
                if !done[shard] {
                    if let Ok(Some(status)) = children[shard].try_wait() {
                        if !status.success() {
                            kill_children(&mut children);
                            bail!(
                                "sweep shard {}/{shards} exited with {status} before \
                                 finishing its cells",
                                shard + 1
                            );
                        }
                    }
                    // still running or exited 0: the wait loop and the
                    // completeness check below decide
                }
            }
        }
    }

    for shard in 0..shards {
        let status = match wait_with_deadline(&mut children[shard], deadline) {
            Ok(status) => status,
            Err(e) => {
                kill_children(&mut children);
                return Err(
                    e.context(format!("waiting for sweep shard {}/{shards}", shard + 1))
                );
            }
        };
        if !status.success() {
            kill_children(&mut children);
            bail!(
                "sweep shard {}/{shards} exited with {status} without reporting an error cell",
                shard + 1
            );
        }
    }

    let mut results = Vec::with_capacity(cells.len());
    for (i, slot) in slots.into_iter().enumerate() {
        results.push(slot.with_context(|| {
            format!(
                "sharded sweep finished without a result for {} (shard {}/{shards})",
                describe_cell(i, &cells[i]),
                i % shards + 1
            )
        })?);
    }
    Ok(SweepReport {
        cells: results,
        workers: opts.workers.max(1),
        grid_hash: spec_grid_hash(spec),
    })
}

// ---------------------------------------------------------------------------
// Report: aggregation, fingerprint, serde, merge
// ---------------------------------------------------------------------------

/// One cell's identity inside [`SweepReport::fingerprint`]: scenario,
/// seed, algorithm, backend, schedule label, cost bits, per-epoch cost
/// bits (empty for static cells), iterations, iters-to-1%.
pub type CellFingerprint = (String, u64, String, String, String, u64, Vec<u64>, usize, usize);

impl CellResult {
    /// Machine-readable cell record. `final_cost` is duplicated as exact
    /// bits (`final_cost_bits`, hex): JSON numbers cannot carry `±∞`
    /// (serialized as `null`) and decimal round-trips are not part of the
    /// determinism contract — the bits field is authoritative for
    /// [`CellResult::from_json`].
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("index", Json::Num(self.index as f64))
            .set("scenario", Json::Str(self.cell.scenario.clone()))
            .set("seed", Json::Num(self.cell.seed as f64))
            .set(
                "algorithm",
                Json::Str(self.cell.algorithm.name().to_string()),
            )
            .set("backend", Json::Str(self.cell.backend.name().to_string()))
            .set("schedule", Json::Str(self.cell.schedule.label()))
            .set("final_cost", Json::Num(self.final_cost))
            .set(
                "final_cost_bits",
                Json::Str(format!("{:016x}", self.final_cost.to_bits())),
            )
            .set("iterations", Json::Num(self.iterations as f64))
            .set("iters_to_1pct", Json::Num(self.iters_to_1pct as f64))
            .set("wall_seconds", Json::Num(self.wall_seconds));
        if !self.epoch_costs.is_empty() {
            o.set(
                "epoch_cost_bits",
                Json::Arr(
                    self.epoch_costs
                        .iter()
                        .map(|c| Json::Str(format!("{:016x}", c.to_bits())))
                        .collect(),
                ),
            );
        }
        o
    }

    /// Parse a cell record produced by [`CellResult::to_json`] (or a
    /// protocol line carrying the same fields).
    pub fn from_json(doc: &Json) -> Result<CellResult> {
        let scenario = doc
            .get("scenario")
            .as_str()
            .context("cell record missing scenario")?
            .to_string();
        let seed = doc.get("seed").as_num().context("cell record missing seed")? as u64;
        let algorithm = {
            let a = doc
                .get("algorithm")
                .as_str()
                .context("cell record missing algorithm")?;
            Algorithm::parse(a).with_context(|| format!("unknown algorithm '{a}'"))?
        };
        let backend = {
            let b = doc
                .get("backend")
                .as_str()
                .context("cell record missing backend")?;
            CellBackend::parse(b).with_context(|| format!("unknown backend '{b}'"))?
        };
        // hand-authored pre-dynamics records may omit the schedule; every
        // writer since the schedule axis emits it, and the grid hash keeps
        // mixed-schedule artifacts from merging regardless
        let schedule = match doc.get("schedule").as_str() {
            Some(s) => PatternSchedule::parse(s)
                .with_context(|| format!("bad cell schedule '{s}'"))?,
            None => PatternSchedule::static_(),
        };
        let epoch_costs = match doc.get("epoch_cost_bits").as_arr() {
            Some(xs) => xs
                .iter()
                .enumerate()
                .map(|(k, x)| {
                    let hex = x
                        .as_str()
                        .with_context(|| format!("epoch_cost_bits[{k}] is not a string"))?;
                    Ok(f64::from_bits(u64::from_str_radix(hex, 16).with_context(
                        || format!("bad epoch_cost_bits[{k}] '{hex}'"),
                    )?))
                })
                .collect::<Result<Vec<_>>>()?,
            None => Vec::new(),
        };
        let final_cost = match doc.get("final_cost_bits").as_str() {
            Some(hex) => f64::from_bits(
                u64::from_str_radix(hex, 16)
                    .with_context(|| format!("bad final_cost_bits '{hex}'"))?,
            ),
            None => {
                // hand-authored records may carry only the decimal field;
                // require it explicitly — a record with *neither* field is
                // corrupt, not saturated. (The serializer writes non-finite
                // costs as JSON null, so an explicit null means +∞.)
                let present = doc
                    .as_obj()
                    .is_some_and(|m| m.contains_key("final_cost"));
                anyhow::ensure!(
                    present,
                    "cell record missing final_cost_bits and final_cost"
                );
                match doc.get("final_cost") {
                    Json::Num(x) => *x,
                    Json::Null => f64::INFINITY,
                    other => bail!(
                        "cell record final_cost must be a number or null, got {other:?}"
                    ),
                }
            }
        };
        Ok(CellResult {
            index: doc
                .get("index")
                .as_usize()
                .context("cell record missing index")?,
            cell: SweepCell {
                scenario,
                seed,
                algorithm,
                backend,
                schedule,
            },
            final_cost,
            iterations: doc
                .get("iterations")
                .as_usize()
                .context("cell record missing iterations")?,
            iters_to_1pct: doc
                .get("iters_to_1pct")
                .as_usize()
                .context("cell record missing iters_to_1pct")?,
            wall_seconds: doc.get("wall_seconds").as_num().unwrap_or(0.0),
            epoch_costs,
        })
    }
}

impl SweepReport {
    /// Per-`(scenario, algorithm, backend, schedule)` aggregates in
    /// first-appearance order.
    pub fn groups(&self) -> Vec<GroupSummary> {
        let mut order: Vec<(String, String, String, String)> = Vec::new();
        let mut buckets: Vec<Vec<&CellResult>> = Vec::new();
        for cell in &self.cells {
            let key = (
                cell.cell.scenario.clone(),
                cell.cell.algorithm.name().to_string(),
                cell.cell.backend.name().to_string(),
                cell.cell.schedule.label(),
            );
            match order.iter().position(|k| *k == key) {
                Some(i) => buckets[i].push(cell),
                None => {
                    order.push(key);
                    buckets.push(vec![cell]);
                }
            }
        }
        order
            .into_iter()
            .zip(buckets)
            .map(|((scenario, algorithm, backend, schedule), cells)| {
                let costs: Vec<f64> = cells.iter().map(|c| c.final_cost).collect();
                let s = summarize(&costs);
                let n = cells.len() as f64;
                GroupSummary {
                    scenario,
                    algorithm,
                    backend,
                    schedule,
                    cells: cells.len(),
                    mean_cost: s.mean,
                    p95_cost: s.p95,
                    mean_iters_to_1pct: cells
                        .iter()
                        .map(|c| c.iters_to_1pct as f64)
                        .sum::<f64>()
                        / n,
                    mean_wall_seconds: cells.iter().map(|c| c.wall_seconds).sum::<f64>() / n,
                }
            })
            .collect()
    }

    /// Deterministic identity of the sweep's results: everything except
    /// wall-clock timing and worker/shard metadata, with costs compared
    /// bit-for-bit. Two sweeps of the same spec must produce equal
    /// fingerprints regardless of worker count
    /// (`rust/tests/sweep_determinism.rs`) or shard count
    /// (`rust/tests/sweep_shard.rs`).
    pub fn fingerprint(&self) -> Vec<CellFingerprint> {
        self.cells
            .iter()
            .map(|c| {
                (
                    c.cell.scenario.clone(),
                    c.cell.seed,
                    c.cell.algorithm.name().to_string(),
                    c.cell.backend.name().to_string(),
                    c.cell.schedule.label(),
                    c.final_cost.to_bits(),
                    c.epoch_costs.iter().map(|x| x.to_bits()).collect(),
                    c.iterations,
                    c.iters_to_1pct,
                )
            })
            .collect()
    }

    /// Paper-style text table of the group aggregates.
    pub fn render(&self) -> String {
        let mut t = Table::new(&[
            "scenario",
            "algo",
            "backend",
            "schedule",
            "cells",
            "mean T",
            "p95 T",
            "iters->1%",
            "mean wall s",
        ]);
        for g in self.groups() {
            t.row(vec![
                g.scenario,
                g.algorithm,
                g.backend,
                g.schedule,
                g.cells.to_string(),
                fnum(g.mean_cost),
                fnum(g.p95_cost),
                format!("{:.1}", g.mean_iters_to_1pct),
                format!("{:.3}", g.mean_wall_seconds),
            ]);
        }
        t.render()
    }

    /// Machine-readable report (cells + groups). Shard reports written
    /// this way are first-class artifacts: [`SweepReport::from_json`] +
    /// [`SweepReport::merge`] reassemble them.
    pub fn to_json(&self) -> Json {
        let cells: Vec<Json> = self.cells.iter().map(CellResult::to_json).collect();
        let groups: Vec<Json> = self
            .groups()
            .into_iter()
            .map(|g| {
                let mut o = Json::obj();
                o.set("scenario", Json::Str(g.scenario))
                    .set("algorithm", Json::Str(g.algorithm))
                    .set("backend", Json::Str(g.backend))
                    .set("schedule", Json::Str(g.schedule))
                    .set("cells", Json::Num(g.cells as f64))
                    .set("mean_cost", Json::Num(g.mean_cost))
                    .set("p95_cost", Json::Num(g.p95_cost))
                    .set("mean_iters_to_1pct", Json::Num(g.mean_iters_to_1pct))
                    .set("mean_wall_seconds", Json::Num(g.mean_wall_seconds));
                o
            })
            .collect();
        let mut doc = Json::obj();
        doc.set("workers", Json::Num(self.workers as f64))
            // hex string: u64 hashes exceed f64's exact-integer range
            .set("grid_hash", Json::Str(format!("{:016x}", self.grid_hash)))
            .set("cells", Json::Arr(cells))
            .set("groups", Json::Arr(groups));
        doc
    }

    /// Parse a report (or shard report) written by [`SweepReport::to_json`].
    /// Cells are re-sorted by their global index; the derived `groups`
    /// section is ignored (it is recomputed on demand).
    pub fn from_json(doc: &Json) -> Result<SweepReport> {
        let cells_json = doc
            .get("cells")
            .as_arr()
            .context("sweep report missing cells array")?;
        let mut cells = cells_json
            .iter()
            .enumerate()
            .map(|(k, c)| CellResult::from_json(c).with_context(|| format!("cell record {k}")))
            .collect::<Result<Vec<_>>>()?;
        cells.sort_by_key(|c| c.index);
        let grid_hash = match doc.get("grid_hash").as_str() {
            Some(hex) => u64::from_str_radix(hex, 16)
                .with_context(|| format!("bad grid_hash '{hex}'"))?,
            None => 0,
        };
        Ok(SweepReport {
            cells,
            workers: doc.get("workers").as_usize().unwrap_or(0),
            grid_hash,
        })
    }

    /// Merge shard reports back into one full-grid report: cells are
    /// reassembled by global index, which must form exactly `0..total`
    /// (duplicates and gaps are contextful errors), and every part must
    /// carry the same [`spec_grid_hash`] — shards of *different* sweeps
    /// with same-sized grids would otherwise interleave silently.
    /// Fingerprint-identical to the single-process run of the same spec.
    pub fn merge(parts: Vec<SweepReport>) -> Result<SweepReport> {
        let mut grid_hash = 0u64;
        for p in &parts {
            if p.grid_hash == 0 {
                continue; // hand-built report: no identity to check
            }
            if grid_hash == 0 {
                grid_hash = p.grid_hash;
            } else if p.grid_hash != grid_hash {
                bail!(
                    "shard merge: reports come from different sweep specs \
                     (grid hash {:016x} vs {:016x})",
                    grid_hash,
                    p.grid_hash
                );
            }
        }
        let workers = parts.iter().map(|p| p.workers).sum::<usize>().max(1);
        let mut cells: Vec<CellResult> = parts.into_iter().flat_map(|p| p.cells).collect();
        anyhow::ensure!(!cells.is_empty(), "merging empty shard reports");
        cells.sort_by_key(|c| c.index);
        for (k, c) in cells.iter().enumerate() {
            if c.index != k {
                if c.index < k {
                    bail!(
                        "shard merge: duplicate result for {}",
                        describe_cell(c.index, &c.cell)
                    );
                }
                bail!(
                    "shard merge: missing cell index {k} — the shard reports do not cover \
                     the whole grid"
                );
            }
        }
        Ok(SweepReport {
            cells,
            workers,
            grid_hash,
        })
    }
}

// ---------------------------------------------------------------------------
// CLI list parsers
// ---------------------------------------------------------------------------

/// Parse a comma-separated scenario list (`"abilene,connected-er"`).
pub fn parse_scenarios(s: &str) -> Vec<String> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(str::to_string)
        .collect()
}

/// Largest seed accepted from the CLI: seeds are reported in JSON, whose
/// numbers are f64, so anything above 2^53 would silently collide with a
/// neighbor in `sweep.json`.
const MAX_SEED: u64 = 1 << 53;

/// Parse a comma-separated seed list (`"1,2,3"`) or an inclusive range
/// (`"1..8"`). Seeds above 2^53 are rejected (not representable in the
/// JSON report).
pub fn parse_seeds(s: &str) -> Result<Vec<u64>> {
    let check = |seed: u64| -> Result<u64> {
        anyhow::ensure!(
            seed <= MAX_SEED,
            "seed {seed} exceeds 2^53 and would lose precision in the JSON report"
        );
        Ok(seed)
    };
    if let Some((lo, hi)) = s.split_once("..") {
        let lo: u64 = lo.trim().parse().context("seed range start")?;
        let hi: u64 = check(hi.trim().parse().context("seed range end")?)?;
        anyhow::ensure!(lo <= hi, "empty seed range {lo}..{hi}");
        return Ok((lo..=hi).collect());
    }
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| {
            t.parse::<u64>()
                .with_context(|| format!("bad seed '{t}'"))
                .and_then(check)
        })
        .collect()
}

/// Parse a comma-separated algorithm list (`"sgp,gp,lpr"`).
pub fn parse_algorithms(s: &str) -> Result<Vec<Algorithm>> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| Algorithm::parse(t).with_context(|| format!("unknown algorithm '{t}'")))
        .collect()
}

/// Parse a comma-separated backend list (`"sparse,native"`).
pub fn parse_backends(s: &str) -> Result<Vec<CellBackend>> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| CellBackend::parse(t).with_context(|| format!("unknown backend '{t}'")))
        .collect()
}

/// Parse a comma-separated schedule list (`"static,step:3:1.5"`) — the
/// `--schedules` CLI flag (re-exported from [`super::dynamics`]).
pub use super::dynamics::parse_schedules;

#[cfg(test)]
mod tests {
    use super::*;

    fn abilene_spec() -> SweepSpec {
        SweepSpec {
            scenarios: vec!["abilene".into()],
            seeds: vec![1, 2],
            algorithms: vec![Algorithm::Sgp, Algorithm::Lpr],
            backends: vec![CellBackend::Sparse],
            schedules: vec![PatternSchedule::static_()],
            rate_scale: 1.0,
            run: RunConfig::quick(),
        }
    }

    #[test]
    fn cell_grid_order_is_canonical() {
        let spec = SweepSpec {
            scenarios: vec!["a".into(), "b".into()],
            seeds: vec![1, 2],
            algorithms: vec![Algorithm::Sgp, Algorithm::Lpr],
            backends: vec![CellBackend::Sparse],
            schedules: vec![PatternSchedule::static_()],
            rate_scale: 1.0,
            run: RunConfig::quick(),
        };
        let cells = spec.cells();
        assert_eq!(cells.len(), 8);
        assert_eq!(cells[0].scenario, "a");
        assert_eq!(cells[0].seed, 1);
        assert_eq!(cells[0].algorithm, Algorithm::Sgp);
        assert_eq!(cells[1].algorithm, Algorithm::Lpr);
        assert_eq!(cells[2].seed, 2);
        assert_eq!(cells[4].scenario, "b");
    }

    #[test]
    fn grid_skips_dense_backends_for_baselines() {
        let spec = SweepSpec {
            scenarios: vec!["a".into()],
            seeds: vec![1],
            algorithms: vec![Algorithm::Sgp, Algorithm::Lpr],
            backends: vec![CellBackend::Sparse, CellBackend::Native],
            schedules: vec![PatternSchedule::static_()],
            rate_scale: 1.0,
            run: RunConfig::quick(),
        };
        let cells = spec.cells();
        // sgp×sparse, sgp×native, lpr×sparse — no lpr×native
        assert_eq!(cells.len(), 3);
        assert_eq!(
            (cells[0].algorithm, cells[0].backend),
            (Algorithm::Sgp, CellBackend::Sparse)
        );
        assert_eq!(
            (cells[1].algorithm, cells[1].backend),
            (Algorithm::Sgp, CellBackend::Native)
        );
        assert_eq!(
            (cells[2].algorithm, cells[2].backend),
            (Algorithm::Lpr, CellBackend::Sparse)
        );
    }

    #[test]
    fn grid_skips_dynamic_schedules_for_non_iterative_algorithms() {
        let spec = SweepSpec {
            scenarios: vec!["a".into()],
            seeds: vec![1],
            algorithms: vec![Algorithm::Sgp, Algorithm::Lpr],
            backends: vec![CellBackend::Sparse],
            schedules: vec![
                PatternSchedule::static_(),
                PatternSchedule::parse("step:3:1.5").unwrap(),
            ],
            rate_scale: 1.0,
            run: RunConfig::quick(),
        };
        let cells = spec.cells();
        // sgp×static, sgp×step, lpr×static — no lpr×step (LPR is one-shot)
        assert_eq!(cells.len(), 3);
        assert!(cells[0].schedule.is_static());
        assert_eq!(cells[1].schedule.label(), "step:3:1.5");
        assert_eq!(cells[1].algorithm, Algorithm::Sgp);
        assert_eq!(cells[2].algorithm, Algorithm::Lpr);
        assert!(cells[2].schedule.is_static());
    }

    #[test]
    fn dynamic_cells_record_per_epoch_costs_and_group_separately() {
        let spec = SweepSpec {
            scenarios: vec!["abilene".into()],
            seeds: vec![1],
            algorithms: vec![Algorithm::Sgp],
            backends: vec![CellBackend::Sparse],
            schedules: vec![
                PatternSchedule::static_(),
                PatternSchedule::parse("step:3:1.5").unwrap(),
            ],
            rate_scale: 1.0,
            run: RunConfig::quick(),
        };
        let report = run_sweep(&spec, 2).unwrap();
        assert_eq!(report.cells.len(), 2);
        assert!(report.cells[0].epoch_costs.is_empty());
        assert_eq!(report.cells[1].epoch_costs.len(), 3);
        assert_eq!(
            report.cells[1].final_cost.to_bits(),
            report.cells[1].epoch_costs[2].to_bits(),
            "a dynamic cell reports its last epoch's cost"
        );
        let groups = report.groups();
        assert_eq!(groups.len(), 2, "schedules must not pool in one group");
        assert_eq!(groups[0].schedule, "static");
        assert_eq!(groups[1].schedule, "step:3:1.5");
        // the schedule axis shows up in the rendered table and the JSON
        assert!(report.render().contains("step:3:1.5"));
        let back = SweepReport::from_json(
            &Json::parse(&report.to_json().pretty()).unwrap(),
        )
        .unwrap();
        assert_eq!(back.fingerprint(), report.fingerprint());
    }

    #[test]
    fn sweep_runs_and_aggregates() {
        let spec = abilene_spec();
        let report = run_sweep(&spec, 2).unwrap();
        assert_eq!(report.cells.len(), 4);
        // indices are the canonical grid positions
        assert_eq!(
            report.cells.iter().map(|c| c.index).collect::<Vec<_>>(),
            vec![0, 1, 2, 3]
        );
        let groups = report.groups();
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0].algorithm, "sgp");
        assert_eq!(groups[0].backend, "sparse");
        assert_eq!(groups[0].cells, 2);
        assert!(groups[0].mean_cost.is_finite());
        // Fig. 4 headline on the means: SGP at or below LPR (same relative
        // tolerance as the fig4 bench's shape check)
        assert!(groups[0].mean_cost <= groups[1].mean_cost * 1.001);
        let txt = report.render();
        assert!(txt.contains("abilene"));
        assert!(txt.contains("sgp"));
        let doc = report.to_json();
        assert_eq!(doc.get("cells").as_arr().unwrap().len(), 4);
    }

    #[test]
    fn unknown_scenario_names_the_cell() {
        let spec = SweepSpec {
            scenarios: vec!["no-such-scenario".into()],
            seeds: vec![1],
            algorithms: vec![Algorithm::Sgp],
            ..SweepSpec::default()
        };
        let err = run_sweep(&spec, 1).unwrap_err().to_string();
        assert!(err.contains("no-such-scenario"), "{err}");
    }

    #[test]
    fn empty_grid_rejected() {
        let spec = SweepSpec {
            scenarios: vec![],
            ..SweepSpec::default()
        };
        assert!(run_sweep(&spec, 1).is_err());
    }

    #[test]
    fn panicking_cell_fails_cleanly_without_deadlock() {
        // Inject a panic into one cell of a real grid: the pool must join
        // all workers, skip unclaimed cells, and surface the panic as that
        // cell's error — not deadlock, not propagate the unwind.
        let spec = SweepSpec {
            scenarios: vec!["abilene".into()],
            seeds: vec![1, 2, 3, 4],
            algorithms: vec![Algorithm::Lpr],
            backends: vec![CellBackend::Sparse],
            schedules: vec![PatternSchedule::static_()],
            rate_scale: 1.0,
            run: RunConfig::quick(),
        };
        let cells: Vec<(usize, SweepCell)> = spec.cells().into_iter().enumerate().collect();
        let err = run_cells_with(
            &cells,
            2,
            |i, c| {
                if i == 1 {
                    panic!("injected cell panic");
                }
                run_cell(i, c, &spec)
            },
            None,
        )
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("injected cell panic"), "{msg}");
        assert!(msg.contains("sweep cell 1"), "{msg}");
    }

    #[test]
    fn shard_indices_partition_the_grid() {
        for count in [1usize, 2, 3, 4, 7] {
            let mut seen = vec![false; 10];
            for shard in 0..count {
                for i in shard_cell_indices(10, shard, count) {
                    assert!(!seen[i], "index {i} assigned twice (count {count})");
                    seen[i] = true;
                    assert_eq!(i % count, shard, "striding violated");
                }
            }
            assert!(seen.iter().all(|&s| s), "indices dropped (count {count})");
        }
    }

    #[test]
    fn in_process_shards_merge_to_the_full_report() {
        let spec = abilene_spec();
        let whole = run_sweep(&spec, 2).unwrap();
        for count in [1usize, 2, 4] {
            let parts: Vec<SweepReport> = (0..count)
                .map(|k| run_sweep_shard(&spec, k, count, 2).unwrap())
                .collect();
            let merged = SweepReport::merge(parts).unwrap();
            assert_eq!(
                merged.fingerprint(),
                whole.fingerprint(),
                "{count} shard(s) drifted from the single-process run"
            );
        }
    }

    #[test]
    fn merge_rejects_gaps_and_duplicates() {
        let spec = abilene_spec();
        let a = run_sweep_shard(&spec, 0, 2, 1).unwrap();
        let b = run_sweep_shard(&spec, 1, 2, 1).unwrap();
        // missing shard
        let err = SweepReport::merge(vec![a.clone()]).unwrap_err().to_string();
        assert!(err.contains("missing cell index"), "{err}");
        // duplicate shard
        let err = SweepReport::merge(vec![a.clone(), a.clone(), b.clone()])
            .unwrap_err()
            .to_string();
        assert!(err.contains("duplicate"), "{err}");
        // correct merge still fine
        assert!(SweepReport::merge(vec![a, b]).is_ok());
    }

    #[test]
    fn report_json_roundtrip_is_bit_exact() {
        // Hand-built report with awkward values (∞ cost from a saturated
        // cell): serde must round-trip the fingerprint exactly even though
        // JSON itself cannot represent ∞.
        let mk = |index: usize, cost: f64| CellResult {
            index,
            cell: SweepCell {
                scenario: "abilene".into(),
                seed: 1 + index as u64,
                algorithm: Algorithm::Sgp,
                backend: CellBackend::Native,
                schedule: PatternSchedule::parse("step:2:1.5").unwrap(),
            },
            final_cost: cost,
            iterations: 5,
            iters_to_1pct: 2,
            wall_seconds: 0.25,
            epoch_costs: vec![123.5, cost],
        };
        let report = SweepReport {
            cells: vec![mk(0, 123.456_789_012_345), mk(1, f64::INFINITY)],
            workers: 3,
            grid_hash: 0xdead_beef_0042_1337,
        };
        let text = report.to_json().pretty();
        let back = SweepReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(report.fingerprint(), back.fingerprint());
        assert!(back.cells[1].final_cost.is_infinite());
        assert_eq!(back.workers, 3);
        assert_eq!(back.grid_hash, report.grid_hash);
    }

    #[test]
    fn merge_rejects_shards_of_different_specs() {
        // equal-sized grids from different specs: index coverage alone
        // would pass, the grid hash must not
        let spec_a = abilene_spec();
        let spec_b = SweepSpec {
            seeds: vec![1, 3],
            ..abilene_spec()
        };
        let a = run_sweep_shard(&spec_a, 0, 2, 1).unwrap();
        let b = run_sweep_shard(&spec_b, 1, 2, 1).unwrap();
        let err = SweepReport::merge(vec![a, b]).unwrap_err().to_string();
        assert!(err.contains("different sweep specs"), "{err}");
    }

    #[test]
    fn oversized_seeds_rejected_before_running() {
        let spec = SweepSpec {
            seeds: vec![(1 << 53) + 1],
            ..abilene_spec()
        };
        let err = run_sweep(&spec, 1).unwrap_err().to_string();
        assert!(err.contains("2^53"), "{err}");
        assert!(run_sweep_shard(&spec, 0, 2, 1).is_err());
    }

    #[test]
    fn corrupt_cell_records_are_rejected_not_defaulted() {
        let base = r#"{"index":0,"scenario":"abilene","seed":1,"algorithm":"sgp",
                       "backend":"sparse","iterations":3,"iters_to_1pct":1,
                       "wall_seconds":0.1"#;
        // neither final_cost_bits nor final_cost: corrupt, not saturated
        let doc = Json::parse(&format!("{base}}}")).unwrap();
        let err = CellResult::from_json(&doc).unwrap_err().to_string();
        assert!(err.contains("final_cost"), "{err}");
        // an explicit null cost (the serializer's spelling of ∞) still loads
        let doc = Json::parse(&format!("{base},\"final_cost\":null}}")).unwrap();
        assert!(CellResult::from_json(&doc).unwrap().final_cost.is_infinite());
        // a missing backend is an error too (every writer emits it)
        let doc = Json::parse(
            r#"{"index":0,"scenario":"abilene","seed":1,"algorithm":"sgp",
                "final_cost":2.5,"iterations":3,"iters_to_1pct":1,"wall_seconds":0.1}"#,
        )
        .unwrap();
        let err = CellResult::from_json(&doc).unwrap_err().to_string();
        assert!(err.contains("backend"), "{err}");
    }

    #[test]
    fn shard_protocol_lines_roundtrip() {
        let cell = CellResult {
            index: 7,
            cell: SweepCell {
                scenario: "connected-er".into(),
                seed: 3,
                algorithm: Algorithm::Gp,
                backend: CellBackend::Sparse,
                schedule: PatternSchedule::parse("bursty:4:2").unwrap(),
            },
            final_cost: f64::INFINITY,
            iterations: 80,
            iters_to_1pct: 80,
            wall_seconds: 1.5,
            epoch_costs: vec![10.0, f64::INFINITY, 9.5, f64::INFINITY],
        };
        match parse_shard_line(&cell_line(&cell)).unwrap() {
            ShardLine::Cell(c) => {
                assert_eq!(c.index, 7);
                assert_eq!(c.cell, cell.cell);
                assert_eq!(c.final_cost.to_bits(), cell.final_cost.to_bits());
                // per-epoch finals travel the protocol bit-exactly, ∞ included
                assert_eq!(
                    c.epoch_costs.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    cell.epoch_costs.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
                );
            }
            other => panic!("wrong line kind: {other:?}"),
        }
        match parse_shard_line(&done_line(1, 9)).unwrap() {
            ShardLine::Done { shard, cells } => {
                assert_eq!((shard, cells), (1, 9));
            }
            other => panic!("wrong line kind: {other:?}"),
        }
        match parse_shard_line(&error_line("boom: cell 3")).unwrap() {
            ShardLine::Error { message } => assert!(message.contains("boom")),
            other => panic!("wrong line kind: {other:?}"),
        }
        assert!(parse_shard_line("not json").is_err());
        assert!(parse_shard_line("{\"type\":\"wat\"}").is_err());
    }

    #[test]
    fn shard_arg_parses_one_based() {
        assert_eq!(parse_shard_arg("1/4").unwrap(), (0, 4));
        assert_eq!(parse_shard_arg("4/4").unwrap(), (3, 4));
        assert!(parse_shard_arg("0/4").is_err());
        assert!(parse_shard_arg("5/4").is_err());
        assert!(parse_shard_arg("x/4").is_err());
        assert!(parse_shard_arg("2").is_err());
    }

    #[test]
    fn spec_args_roundtrip_through_the_parsers() {
        let spec = SweepSpec {
            scenarios: vec!["abilene".into(), "connected-er".into()],
            seeds: vec![1, 5, 9],
            algorithms: vec![Algorithm::Sgp, Algorithm::Gp],
            backends: vec![CellBackend::Sparse, CellBackend::Native],
            schedules: vec![
                PatternSchedule::static_(),
                PatternSchedule::parse("step:3:1.5").unwrap(),
            ],
            rate_scale: 1.25,
            run: RunConfig {
                max_iters: 33,
                tol: 3e-6,
                patience: 4,
            },
        };
        let args = spec_to_args(&spec);
        let get = |flag: &str| -> &str {
            let i = args.iter().position(|a| a == flag).unwrap();
            &args[i + 1]
        };
        assert_eq!(parse_scenarios(get("--scenarios")), spec.scenarios);
        assert_eq!(parse_seeds(get("--seeds")).unwrap(), spec.seeds);
        assert_eq!(parse_algorithms(get("--algos")).unwrap(), spec.algorithms);
        assert_eq!(parse_backends(get("--backends")).unwrap(), spec.backends);
        assert_eq!(parse_schedules(get("--schedules")).unwrap(), spec.schedules);
        assert_eq!(get("--scale").parse::<f64>().unwrap(), spec.rate_scale);
        assert_eq!(get("--iters").parse::<usize>().unwrap(), 33);
        assert_eq!(get("--tol").parse::<f64>().unwrap().to_bits(), 3e-6f64.to_bits());
        assert_eq!(get("--patience").parse::<usize>().unwrap(), 4);
    }

    #[test]
    fn list_parsers() {
        assert_eq!(parse_scenarios("a, b,"), vec!["a", "b"]);
        assert_eq!(parse_seeds("1, 2,3").unwrap(), vec![1, 2, 3]);
        assert_eq!(parse_seeds("4..6").unwrap(), vec![4, 5, 6]);
        assert!(parse_seeds("9..2").is_err());
        assert!(parse_seeds("x").is_err());
        // seeds past 2^53 would alias in the f64-backed JSON report
        assert!(parse_seeds("9007199254740993").is_err());
        assert_eq!(
            parse_algorithms("sgp,lpr").unwrap(),
            vec![Algorithm::Sgp, Algorithm::Lpr]
        );
        assert!(parse_algorithms("sgp,zzz").is_err());
        assert_eq!(
            parse_backends("sparse, native").unwrap(),
            vec![CellBackend::Sparse, CellBackend::Native]
        );
        assert!(parse_backends("sparse,zzz").is_err());
    }
}
