//! PJRT runtime: loads the AOT-compiled `dense_eval` HLO artifacts
//! produced by `python/compile/aot.py` and executes them from the rust hot
//! path. Python never runs at request time — artifacts are bytes on disk.

pub mod dense;
pub mod engine;
pub mod manifest;

pub use dense::{DenseEval, DenseEvaluator};
pub use engine::{DenseInputs, DenseOutputs, Engine};
pub use manifest::Manifest;

use std::path::PathBuf;

/// Default artifacts directory: `$CECFLOW_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("CECFLOW_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
