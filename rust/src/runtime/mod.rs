//! Dense-evaluation runtime with pluggable backends.
//!
//! * [`backend::NativeBackend`] — the default data plane: exact pure-rust
//!   f64 evaluation. Always built; needs no artifacts.
//! * [`engine::Engine`] + [`dense::DenseEvaluator`] (cargo feature
//!   `pjrt`) — loads the AOT-compiled `dense_eval` HLO artifacts produced
//!   by `python/compile/aot.py` (`make artifacts`) and executes them
//!   through the PJRT CPU client. Python never runs at request time —
//!   artifacts are bytes on disk.

pub mod backend;
pub mod dense;
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod manifest;

pub use backend::{DenseBackend, NativeBackend};
#[cfg(feature = "pjrt")]
pub use dense::DenseEvaluator;
pub use dense::{DenseEval, DenseInputs, DenseOutputs};
#[cfg(feature = "pjrt")]
pub use engine::Engine;
pub use manifest::Manifest;

use std::path::{Path, PathBuf};

use anyhow::Result;

/// Default artifacts directory: `$CECFLOW_ARTIFACTS` or `./artifacts`.
///
/// This only names the location; use [`resolve_artifacts_dir`] when the
/// caller needs the directory to actually exist.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("CECFLOW_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// [`default_artifacts_dir`], validated: returns a contextful error when
/// the directory is missing instead of letting downstream file reads fail
/// with a bare "No such file or directory".
pub fn resolve_artifacts_dir() -> Result<PathBuf> {
    let dir = default_artifacts_dir();
    ensure_artifacts_dir(&dir)?;
    Ok(dir)
}

/// Single source of truth for the missing-artifacts-directory error
/// (shared by [`resolve_artifacts_dir`] and `Manifest::load`).
pub(crate) fn ensure_artifacts_dir(dir: &Path) -> Result<()> {
    anyhow::ensure!(
        dir.is_dir(),
        "artifacts directory {dir:?} does not exist — set $CECFLOW_ARTIFACTS to the AOT \
         output directory or generate it with `make artifacts`"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    // One test (not several) so parallel test threads never race on the
    // process-global CECFLOW_ARTIFACTS variable.
    #[test]
    fn artifacts_dir_resolution_and_errors() {
        // env var overrides the default location
        std::env::set_var("CECFLOW_ARTIFACTS", "/tmp/somewhere-else");
        assert_eq!(
            default_artifacts_dir(),
            PathBuf::from("/tmp/somewhere-else")
        );

        // a missing directory must error with actionable context rather
        // than panic or let downstream file reads fail bare
        std::env::set_var(
            "CECFLOW_ARTIFACTS",
            std::env::temp_dir().join(format!("cecflow-noexist-{}", std::process::id())),
        );
        let err = resolve_artifacts_dir().unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
        assert!(err.contains("CECFLOW_ARTIFACTS"), "{err}");

        std::env::remove_var("CECFLOW_ARTIFACTS");
        assert_eq!(default_artifacts_dir(), PathBuf::from("artifacts"));
    }
}
