//! PJRT execution engine: loads the AOT HLO-text artifacts and runs them
//! on the CPU client from the rust hot path. Only compiled when the
//! `pjrt` cargo feature is enabled — the default data plane is the
//! pure-rust [`super::backend::NativeBackend`].
//!
//! Wiring follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `PjRtClient::compile` → `execute`.
//! One compiled executable per size class, compiled once at startup.

use std::path::Path;

use anyhow::{Context, Result};

use super::dense::{DenseInputs, DenseOutputs};
use super::manifest::{Manifest, SizeClass};

/// A compiled `dense_eval` executable for one size class.
pub struct CompiledClass {
    pub n: usize,
    pub s: usize,
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

/// The engine: PJRT client + one executable per size class.
pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    compiled: Vec<CompiledClass>,
}

impl Engine {
    /// Load the manifest and compile every artifact on the CPU client.
    pub fn load(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut compiled = Vec::new();
        for class in &manifest.classes {
            let exe = Self::compile_class(&client, class)
                .with_context(|| format!("compiling size class {}", class.name))?;
            compiled.push(CompiledClass {
                n: class.n,
                s: class.s,
                name: class.name.clone(),
                exe,
            });
        }
        Ok(Engine {
            manifest,
            client,
            compiled,
        })
    }

    /// Load only the classes that satisfy `pred` (examples use this to skip
    /// the large class for faster startup).
    pub fn load_filtered(
        artifacts_dir: &Path,
        pred: impl Fn(&SizeClass) -> bool,
    ) -> Result<Engine> {
        let mut manifest = Manifest::load(artifacts_dir)?;
        manifest.classes.retain(|c| pred(c));
        anyhow::ensure!(
            !manifest.classes.is_empty(),
            "class filter removed every size class"
        );
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut compiled = Vec::new();
        for class in &manifest.classes {
            let exe = Self::compile_class(&client, class)?;
            compiled.push(CompiledClass {
                n: class.n,
                s: class.s,
                name: class.name.clone(),
                exe,
            });
        }
        Ok(Engine {
            manifest,
            client,
            compiled,
        })
    }

    fn compile_class(
        client: &xla::PjRtClient,
        class: &SizeClass,
    ) -> Result<xla::PjRtLoadedExecutable> {
        let path_str = class
            .file
            .to_str()
            .context("artifact path is not valid UTF-8")?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parsing HLO text {path_str}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client
            .compile(&comp)
            .with_context(|| format!("PJRT compile of {path_str}"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn classes(&self) -> &[CompiledClass] {
        &self.compiled
    }

    /// Pick the smallest compiled class that fits `(n, s)`.
    pub fn class_for(&self, n: usize, s: usize) -> Option<&CompiledClass> {
        self.compiled
            .iter()
            .filter(|c| c.n >= n && c.s >= s)
            .min_by_key(|c| (c.n, c.s))
    }

    /// Execute `dense_eval` on pre-padded inputs (must match a compiled
    /// class exactly — use [`Engine::class_for`] + the packer in
    /// `runtime::dense`).
    pub fn run(&self, inputs: &DenseInputs) -> Result<DenseOutputs> {
        let class = self.exact_class(inputs.n, inputs.s)?;
        self.run_on_class(class, inputs)
    }

    /// The compiled class of *exactly* `(n, s)` (pre-padded inputs must
    /// match a class; use [`Engine::class_for`] to pick one to pad to).
    fn exact_class(&self, n: usize, s: usize) -> Result<&CompiledClass> {
        self.compiled
            .iter()
            .find(|c| c.n == n && c.s == s)
            .with_context(|| format!("no compiled class of exact size N={n} S={s}"))
    }

    /// Execute `dense_eval` for a whole batch of pre-padded inputs in one
    /// engine dispatch: the compiled class is resolved once and every
    /// candidate runs on that executable back-to-back, keeping the device
    /// hot between launches. All inputs must share one padding class —
    /// `DenseEvaluator::evaluate_batch` packs them that way. (The AOT
    /// artifact has no leading batch dimension yet; once
    /// `python/compile/aot.py` grows one, this is the single place that
    /// switches to a literally-one-launch execution.)
    pub fn run_batch(&self, inputs: &[DenseInputs]) -> Result<Vec<DenseOutputs>> {
        let Some(first) = inputs.first() else {
            return Ok(Vec::new());
        };
        anyhow::ensure!(
            inputs.iter().all(|i| i.n == first.n && i.s == first.s),
            "run_batch requires uniformly padded inputs (first is N={} S={})",
            first.n,
            first.s
        );
        let class = self.exact_class(first.n, first.s)?;
        inputs
            .iter()
            .map(|inp| self.run_on_class(class, inp))
            .collect()
    }

    fn run_on_class(&self, class: &CompiledClass, inputs: &DenseInputs) -> Result<DenseOutputs> {
        let n = inputs.n as i64;
        let s = inputs.s as i64;

        let lit = |data: &[f32], dims: &[i64]| -> Result<xla::Literal> {
            let flat = xla::Literal::vec1(data);
            Ok(flat.reshape(dims)?)
        };
        let args: Vec<xla::Literal> = vec![
            lit(&inputs.phi_data, &[s, n, n])?,
            lit(&inputs.phi_local, &[s, n])?,
            lit(&inputs.phi_result, &[s, n, n])?,
            lit(&inputs.r, &[s, n])?,
            lit(&inputs.a, &[s])?,
            lit(&inputs.w, &[s, n])?,
            lit(&inputs.link_param, &[n, n])?,
            lit(&inputs.link_kind, &[n, n])?,
            lit(&inputs.link_mask, &[n, n])?,
            lit(&inputs.comp_param, &[n])?,
            lit(&inputs.comp_kind, &[n])?,
        ];

        let result = class.exe.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let parts = result.to_tuple().context("decomposing result tuple")?;
        anyhow::ensure!(
            parts.len() == 9,
            "artifact returned {} outputs, expected 9",
            parts.len()
        );
        let mut it = parts.into_iter();
        let total: f32 = it.next().unwrap().to_vec::<f32>()?[0];
        let next_vec = |it: &mut std::vec::IntoIter<xla::Literal>| -> Result<Vec<f32>> {
            Ok(it.next().unwrap().to_vec::<f32>()?)
        };
        let link_flow = next_vec(&mut it)?;
        let workload = next_vec(&mut it)?;
        let dp_link = next_vec(&mut it)?;
        let cp_node = next_vec(&mut it)?;
        let dt_plus = next_vec(&mut it)?;
        let dt_r = next_vec(&mut it)?;
        let t_minus = next_vec(&mut it)?;
        let t_plus = next_vec(&mut it)?;

        // saturation sentinel → true infinity
        let total_cost = if (total as f64) >= self.manifest.sat_big {
            f64::INFINITY
        } else {
            total as f64
        };

        Ok(DenseOutputs {
            n: inputs.n,
            s: inputs.s,
            total_cost,
            link_flow,
            workload,
            dp_link,
            cp_node,
            dt_plus,
            dt_r,
            t_minus,
            t_plus,
        })
    }
}
