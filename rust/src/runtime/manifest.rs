//! `artifacts/manifest.json` — the contract between the python AOT step
//! and the rust runtime: artifact file names, size classes (padding
//! bounds), and the tensor input/output orders of the `dense_eval` entry.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// One padded size class (`N` nodes, `S` tasks) with its HLO artifact.
#[derive(Clone, Debug)]
pub struct SizeClass {
    pub name: String,
    pub file: PathBuf,
    pub n: usize,
    pub s: usize,
    /// Propagation wave count baked into the artifact.
    pub iters: usize,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
    /// Values at/above this are "saturated" (the f32 stand-in for +∞).
    pub sat_big: f64,
    pub classes: Vec<SizeClass>,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    ///
    /// Every failure on the no-artifact path (missing directory, missing
    /// manifest, missing artifact files) returns a contextful error that
    /// says how to produce the artifacts — never a panic.
    pub fn load(dir: &Path) -> Result<Manifest> {
        super::ensure_artifacts_dir(dir)?;
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let root = Json::parse(&text).context("parsing manifest.json")?;
        if root.get("format").as_str() != Some("hlo-text") {
            bail!("unsupported artifact format {:?}", root.get("format"));
        }
        let strings = |key: &str| -> Result<Vec<String>> {
            root.get(key)
                .as_arr()
                .with_context(|| format!("manifest missing {key}"))?
                .iter()
                .map(|v| {
                    v.as_str()
                        .map(str::to_string)
                        .with_context(|| format!("non-string in {key}"))
                })
                .collect()
        };
        let inputs = strings("inputs")?;
        let outputs = strings("outputs")?;
        let sat_big = root.get("sat_big").as_num().unwrap_or(1e30);
        let mut classes = Vec::new();
        for c in root
            .get("classes")
            .as_arr()
            .context("manifest missing classes")?
        {
            classes.push(SizeClass {
                name: c
                    .get("name")
                    .as_str()
                    .context("class missing name")?
                    .to_string(),
                file: dir.join(c.get("file").as_str().context("class missing file")?),
                n: c.get("n").as_usize().context("class missing n")?,
                s: c.get("s").as_usize().context("class missing s")?,
                iters: c.get("iters").as_usize().context("class missing iters")?,
            });
        }
        if classes.is_empty() {
            bail!("manifest has no size classes");
        }
        let m = Manifest {
            inputs,
            outputs,
            sat_big,
            classes,
        };
        m.validate()?;
        Ok(m)
    }

    fn validate(&self) -> Result<()> {
        if self.inputs.len() != 11 {
            bail!("expected 11 inputs, manifest lists {}", self.inputs.len());
        }
        if self.outputs.len() != 9 {
            bail!("expected 9 outputs, manifest lists {}", self.outputs.len());
        }
        for c in &self.classes {
            if !c.file.exists() {
                bail!(
                    "artifact file missing: {:?} (listed in manifest.json — re-run \
                     `make artifacts` to regenerate the HLO artifacts)",
                    c.file
                );
            }
            if c.n == 0 || c.s == 0 {
                bail!("degenerate size class {}", c.name);
            }
        }
        Ok(())
    }

    /// Smallest class fitting a network with `n` nodes and `s` tasks.
    pub fn class_for(&self, n: usize, s: usize) -> Option<&SizeClass> {
        self.classes
            .iter()
            .filter(|c| c.n >= n && c.s >= s)
            .min_by_key(|c| (c.n, c.s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fixture(dir: &Path, with_files: bool) {
        let manifest = r#"{
            "format": "hlo-text",
            "entry": "dense_eval",
            "inputs": ["phi_data","phi_local","phi_result","r","a","w",
                       "link_param","link_kind","link_mask","comp_param","comp_kind"],
            "outputs": ["total_cost","link_flow","workload","dp_link","cp_node",
                        "dt_plus","dt_r","t_minus","t_plus"],
            "sat_big": 1e30,
            "classes": [
                {"name":"small","file":"dense_eval_small.hlo.txt","n":32,"s":48,"iters":32},
                {"name":"large","file":"dense_eval_large.hlo.txt","n":128,"s":128,"iters":128}
            ]
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        if with_files {
            std::fs::write(dir.join("dense_eval_small.hlo.txt"), "HloModule x").unwrap();
            std::fs::write(dir.join("dense_eval_large.hlo.txt"), "HloModule x").unwrap();
        }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("cecflow-manifest-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn loads_valid_manifest() {
        let dir = tmpdir("ok");
        write_fixture(&dir, true);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.classes.len(), 2);
        assert_eq!(m.inputs[0], "phi_data");
        assert_eq!(m.sat_big, 1e30);
    }

    #[test]
    fn class_selection_smallest_fitting() {
        let dir = tmpdir("sel");
        write_fixture(&dir, true);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.class_for(10, 10).unwrap().name, "small");
        assert_eq!(m.class_for(32, 48).unwrap().name, "small");
        assert_eq!(m.class_for(33, 10).unwrap().name, "large");
        assert_eq!(m.class_for(100, 120).unwrap().name, "large");
        assert!(m.class_for(500, 10).is_none());
    }

    #[test]
    fn missing_artifact_file_rejected() {
        let dir = tmpdir("missing");
        write_fixture(&dir, false);
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn missing_manifest_errors_helpfully() {
        let dir = tmpdir("nofile");
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
