//! Pluggable dense-evaluation backends.
//!
//! The SGP accelerated path ([`crate::algo::Sgp::step_dense`] /
//! [`crate::coordinator::optimize_accelerated`]) needs one thing from its
//! data plane: given `(network, strategy)`, produce the full
//! [`DenseEval`] — total cost, aggregate flows, link/node marginal prices,
//! and the per-task traffic and marginal fields of §II–§III. The
//! [`DenseBackend`] trait captures exactly that contract, so the control
//! plane (blocked sets, scaling matrices, projection QP, descent
//! safeguard) is backend-agnostic.
//!
//! Two implementations exist:
//!
//! * [`NativeBackend`] (this module) — the default: exact, pure-rust f64
//!   evaluation via [`crate::model::flows`] + [`crate::model::marginals`].
//!   Always available; no artifacts, no external libraries.
//! * `DenseEvaluator` (`runtime::dense`, behind the `pjrt` cargo feature)
//!   — the AOT `dense_eval` HLO artifact executed on the PJRT CPU client
//!   (f32 data plane; see `rust/tests/xla_parity.rs` for the parity
//!   tolerances).
//!
//! Callers select a backend per use: `optimize_accelerated` takes
//! `&dyn DenseBackend` directly, and sweep cells pick one through
//! `coordinator::CellBackend` (`cecflow sweep --backends sparse,native`),
//! so a single grid prices both data planes side by side.

use anyhow::Result;

use crate::model::flows::{compute_flows, compute_flows_into, FlowState};
use crate::model::marginals::compute_marginals;
use crate::model::network::Network;
use crate::model::strategy::Strategy;

use super::dense::DenseEval;

/// A dense data-plane backend: evaluates flows + marginals for a
/// `(network, strategy)` pair.
///
/// Implementations must only be called on loop-free strategies (callers
/// check `Strategy::is_loop_free` first — the SGP safeguard already does).
pub trait DenseBackend {
    /// Short backend name, used in run labels (e.g. `sgp-native`).
    fn name(&self) -> &'static str;

    /// Evaluate the full dense state for `(net, phi)`.
    fn evaluate(&self, net: &Network, phi: &Strategy) -> Result<DenseEval>;

    /// Evaluate several candidate strategies against the *same* network in
    /// one backend call — the SGP safeguard prices its whole retry ladder
    /// through this entry point.
    ///
    /// Contract (pinned by `rust/tests/batch_parity.rs`):
    /// * `evaluate_batch(net, cands)?[k]` equals `evaluate(net, &cands[k])?`
    ///   for every `k` — including saturation (`total_cost = +∞`) and the
    ///   marginal fields. For `NativeBackend` the equality is bitwise.
    /// * An error on any candidate (e.g. a routing loop) fails the whole
    ///   batch, exactly as the per-candidate call would.
    /// * An empty batch returns an empty vec.
    ///
    /// The default implementation loops over [`DenseBackend::evaluate`];
    /// backends override it to amortize work across candidates
    /// (`NativeBackend` reuses one set of flow buffers, the PJRT engine
    /// resolves the size class and compiled executable once per batch).
    fn evaluate_batch(&self, net: &Network, candidates: &[Strategy]) -> Result<Vec<DenseEval>> {
        candidates
            .iter()
            .map(|phi| self.evaluate(net, phi))
            .collect()
    }
}

/// The default backend: exact f64 evaluation on the sparse native model.
///
/// This is the always-buildable data plane — the PJRT engine behind the
/// `pjrt` feature is an optional drop-in replacement for large padded
/// instances.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend
    }
}

impl DenseBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn evaluate(&self, net: &Network, phi: &Strategy) -> Result<DenseEval> {
        let flows = compute_flows(net, phi).map_err(anyhow::Error::new)?;
        let marg = compute_marginals(net, phi, &flows).map_err(anyhow::Error::new)?;
        Ok(DenseEval {
            total_cost: flows.total_cost,
            d_link: marg.d_link,
            c_node: marg.c_node,
            dt_plus: marg.dt_plus,
            dt_r: marg.dt_r,
            t_minus: flows.t_minus,
            t_plus: flows.t_plus,
            link_flow: flows.link_flow,
            workload: flows.workload,
        })
    }

    /// Single-pass batch evaluation: one `O(|S|·|E|)` flow scratch is
    /// allocated up front and refilled per candidate
    /// ([`compute_flows_into`] performs the exact arithmetic of
    /// `compute_flows`, so every result is bitwise identical to the
    /// per-candidate path — only the per-candidate allocations of the
    /// task×edge flow planes are gone).
    fn evaluate_batch(&self, net: &Network, candidates: &[Strategy]) -> Result<Vec<DenseEval>> {
        let mut scratch = FlowState::zeroed(net);
        let mut out = Vec::with_capacity(candidates.len());
        for phi in candidates {
            compute_flows_into(net, phi, &mut scratch).map_err(anyhow::Error::new)?;
            let marg = compute_marginals(net, phi, &scratch).map_err(anyhow::Error::new)?;
            out.push(DenseEval {
                total_cost: scratch.total_cost,
                d_link: marg.d_link,
                c_node: marg.c_node,
                dt_plus: marg.dt_plus,
                dt_r: marg.dt_r,
                t_minus: scratch.t_minus.clone(),
                t_plus: scratch.t_plus.clone(),
                link_flow: scratch.link_flow.clone(),
                workload: scratch.workload.clone(),
            });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::network::testnet::{diamond, line3};

    #[test]
    fn native_backend_matches_direct_model_evaluation() {
        for net in [diamond(true), diamond(false), line3()] {
            let phi = Strategy::local_compute_init(&net);
            let flows = compute_flows(&net, &phi).unwrap();
            let marg = compute_marginals(&net, &phi, &flows).unwrap();
            let ev = NativeBackend.evaluate(&net, &phi).unwrap();
            assert_eq!(ev.total_cost, flows.total_cost);
            assert_eq!(ev.link_flow, flows.link_flow);
            assert_eq!(ev.workload, flows.workload);
            assert_eq!(ev.t_minus, flows.t_minus);
            assert_eq!(ev.t_plus, flows.t_plus);
            assert_eq!(ev.d_link, marg.d_link);
            assert_eq!(ev.c_node, marg.c_node);
            assert_eq!(ev.dt_plus, marg.dt_plus);
            assert_eq!(ev.dt_r, marg.dt_r);
        }
    }

    #[test]
    fn native_backend_reports_saturation_as_infinity() {
        let mut net = diamond(true);
        net.input_rate[0][0] = 100.0; // beyond the comp capacity of 12
        let phi = Strategy::local_compute_init(&net);
        let ev = NativeBackend.evaluate(&net, &phi).unwrap();
        assert!(ev.total_cost.is_infinite());
    }

    #[test]
    fn usable_as_trait_object() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let backend: &dyn DenseBackend = &NativeBackend;
        assert_eq!(backend.name(), "native");
        assert!(backend.evaluate(&net, &phi).unwrap().total_cost.is_finite());
    }

    #[test]
    fn batch_matches_per_candidate_evaluation() {
        let net = diamond(true);
        let cands = [
            Strategy::local_compute_init(&net),
            Strategy::compute_at_dest_init(&net),
            Strategy::local_compute_init(&net),
        ];
        let batch = NativeBackend.evaluate_batch(&net, &cands).unwrap();
        assert_eq!(batch.len(), cands.len());
        for (phi, ev) in cands.iter().zip(&batch) {
            let solo = NativeBackend.evaluate(&net, phi).unwrap();
            assert_eq!(ev.total_cost.to_bits(), solo.total_cost.to_bits());
            assert_eq!(ev.link_flow, solo.link_flow);
            assert_eq!(ev.workload, solo.workload);
            assert_eq!(ev.dt_plus, solo.dt_plus);
            assert_eq!(ev.dt_r, solo.dt_r);
        }
    }

    #[test]
    fn batch_of_empty_and_single() {
        let net = diamond(true);
        assert!(NativeBackend.evaluate_batch(&net, &[]).unwrap().is_empty());
        let one = [Strategy::local_compute_init(&net)];
        assert_eq!(NativeBackend.evaluate_batch(&net, &one).unwrap().len(), 1);
    }
}
