//! Dense tensor layout shared by all accelerated backends: `Network` +
//! `Strategy` → padded row-major tensors for the AOT `dense_eval`
//! artifact, and unpacking of its outputs back into the sparse model
//! shapes. The [`DenseEval`] struct is also the return type of every
//! [`super::backend::DenseBackend`], so pack/unpack and the backend
//! abstraction agree on indexing.
//!
//! Padding identity: padded nodes are isolated (link mask 0, zero rates,
//! `φ_local = 1`) and padded tasks carry zero input — every padded slot
//! contributes exactly 0 to cost and marginals, which the parity test in
//! `rust/tests/xla_parity.rs` pins against the native evaluator.

use anyhow::Result;

use crate::model::cost::CostFn;
use crate::model::network::Network;
use crate::model::strategy::Strategy;

/// Dense evaluation results mapped back to model indexing.
#[derive(Clone, Debug)]
pub struct DenseEval {
    pub total_cost: f64,
    /// `D'` per directed edge id.
    pub d_link: Vec<f64>,
    /// `C'` per node.
    pub c_node: Vec<f64>,
    /// `∂T/∂t⁺` `[task][node]`.
    pub dt_plus: Vec<Vec<f64>>,
    /// `∂T/∂r` `[task][node]`.
    pub dt_r: Vec<Vec<f64>>,
    /// `t⁻` / `t⁺` `[task][node]`.
    pub t_minus: Vec<Vec<f64>>,
    pub t_plus: Vec<Vec<f64>>,
    /// Aggregate flow per directed edge id.
    pub link_flow: Vec<f64>,
    /// Workload per node.
    pub workload: Vec<f64>,
}

/// Raw dense inputs, already padded to a size class. All row-major f32.
#[derive(Clone, Debug)]
pub struct DenseInputs {
    pub n: usize,
    pub s: usize,
    pub phi_data: Vec<f32>,   // [S*N*N]
    pub phi_local: Vec<f32>,  // [S*N]
    pub phi_result: Vec<f32>, // [S*N*N]
    pub r: Vec<f32>,          // [S*N]
    pub a: Vec<f32>,          // [S]
    pub w: Vec<f32>,          // [S*N]
    pub link_param: Vec<f32>, // [N*N]
    pub link_kind: Vec<f32>,  // [N*N]
    pub link_mask: Vec<f32>,  // [N*N]
    pub comp_param: Vec<f32>, // [N]
    pub comp_kind: Vec<f32>,  // [N]
}

/// Dense outputs as returned by the artifact.
#[derive(Clone, Debug)]
pub struct DenseOutputs {
    pub n: usize,
    pub s: usize,
    pub total_cost: f64,
    pub link_flow: Vec<f32>, // [N*N]
    pub workload: Vec<f32>,  // [N]
    pub dp_link: Vec<f32>,   // [N*N]
    pub cp_node: Vec<f32>,   // [N]
    pub dt_plus: Vec<f32>,   // [S*N]
    pub dt_r: Vec<f32>,      // [S*N]
    pub t_minus: Vec<f32>,   // [S*N]
    pub t_plus: Vec<f32>,    // [S*N]
}

impl DenseInputs {
    /// Zero-filled inputs for a size class (padding identity: zero rates,
    /// zero routing, masked-out links, local fractions set to 1 for
    /// padding rows so simplexes stay valid — all costs stay 0).
    pub fn zeroed(n: usize, s: usize) -> DenseInputs {
        DenseInputs {
            n,
            s,
            phi_data: vec![0.0; s * n * n],
            phi_local: vec![1.0; s * n],
            phi_result: vec![0.0; s * n * n],
            r: vec![0.0; s * n],
            a: vec![1.0; s],
            w: vec![1.0; s * n],
            link_param: vec![0.0; n * n],
            link_kind: vec![0.0; n * n],
            link_mask: vec![0.0; n * n],
            comp_param: vec![0.0; n],
            comp_kind: vec![0.0; n],
        }
    }
}

/// Pack a network + strategy into `DenseInputs` padded for `(pn, ps)`.
pub fn pack(net: &Network, phi: &Strategy, pn: usize, ps: usize) -> Result<DenseInputs> {
    let n = net.n();
    let s = net.s();
    anyhow::ensure!(pn >= n && ps >= s, "padding smaller than network");
    let mut inp = DenseInputs::zeroed(pn, ps);

    for (eid, e) in net.graph.edges().iter().enumerate() {
        let idx = e.src * pn + e.dst;
        inp.link_mask[idx] = 1.0;
        match net.link_cost[eid] {
            CostFn::Linear { unit } => {
                inp.link_kind[idx] = 0.0;
                inp.link_param[idx] = unit as f32;
            }
            CostFn::Queue { cap } => {
                inp.link_kind[idx] = 1.0;
                inp.link_param[idx] = cap as f32;
            }
            CostFn::SmoothCap { .. } => {
                anyhow::bail!("SmoothCap links are not represented in the AOT artifact")
            }
        }
    }
    for i in 0..n {
        match net.comp_cost[i] {
            CostFn::Linear { unit } => {
                inp.comp_kind[i] = 0.0;
                inp.comp_param[i] = unit as f32;
            }
            CostFn::Queue { cap } => {
                inp.comp_kind[i] = 1.0;
                inp.comp_param[i] = cap as f32;
            }
            CostFn::SmoothCap { .. } => {
                anyhow::bail!("SmoothCap nodes are not represented in the AOT artifact")
            }
        }
    }

    for task in 0..s {
        let a = net.a_of(task);
        inp.a[task] = a as f32;
        for i in 0..n {
            inp.r[task * pn + i] = net.input_rate[task][i] as f32;
            inp.w[task * pn + i] = net.w_of(i, task) as f32;
            inp.phi_local[task * pn + i] = phi.data[task][i][0] as f32;
            for (k, &eid) in net.graph.out_edge_ids(i).iter().enumerate() {
                let j = net.graph.edge(eid).dst;
                inp.phi_data[task * pn * pn + i * pn + j] = phi.data[task][i][k + 1] as f32;
                inp.phi_result[task * pn * pn + i * pn + j] = phi.result[task][i][k] as f32;
            }
        }
    }
    Ok(inp)
}

/// Unpack padded outputs back to edge-id / node / task indexing.
pub fn unpack(net: &Network, out: &DenseOutputs) -> DenseEval {
    let n = net.n();
    let s = net.s();
    let pn = out.n;
    let d_link: Vec<f64> = net
        .graph
        .edges()
        .iter()
        .map(|e| out.dp_link[e.src * pn + e.dst] as f64)
        .collect();
    let link_flow: Vec<f64> = net
        .graph
        .edges()
        .iter()
        .map(|e| out.link_flow[e.src * pn + e.dst] as f64)
        .collect();
    let c_node: Vec<f64> = (0..n).map(|i| out.cp_node[i] as f64).collect();
    let workload: Vec<f64> = (0..n).map(|i| out.workload[i] as f64).collect();
    let grab = |flat: &[f32]| -> Vec<Vec<f64>> {
        (0..s)
            .map(|task| (0..n).map(|i| flat[task * pn + i] as f64).collect())
            .collect()
    };
    DenseEval {
        total_cost: out.total_cost,
        d_link,
        c_node,
        dt_plus: grab(&out.dt_plus),
        dt_r: grab(&out.dt_r),
        t_minus: grab(&out.t_minus),
        t_plus: grab(&out.t_plus),
        link_flow,
        workload,
    }
}

/// High-level accelerated evaluator: pads, runs the artifact, unpacks.
/// This is the PJRT implementation of [`super::backend::DenseBackend`];
/// the always-available default is [`super::backend::NativeBackend`].
#[cfg(feature = "pjrt")]
pub struct DenseEvaluator<'e> {
    engine: &'e super::engine::Engine,
}

#[cfg(feature = "pjrt")]
impl<'e> DenseEvaluator<'e> {
    pub fn new(engine: &'e super::engine::Engine) -> Self {
        DenseEvaluator { engine }
    }

    /// Evaluate flows + marginals for `(net, phi)` on the XLA data plane.
    pub fn evaluate(&self, net: &Network, phi: &Strategy) -> Result<DenseEval> {
        use anyhow::Context as _;
        let class = self
            .engine
            .class_for(net.n(), net.s())
            .with_context(|| {
                format!(
                    "no size class fits N={} S={} (largest: {:?})",
                    net.n(),
                    net.s(),
                    self.engine
                        .classes()
                        .iter()
                        .map(|c| (c.n, c.s))
                        .max()
                )
            })?;
        let inputs = pack(net, phi, class.n, class.s)?;
        let out = self.engine.run(&inputs)?;
        Ok(unpack(net, &out))
    }

    /// Batched evaluation on the XLA data plane: every candidate is packed
    /// into the *same* size class (resolved once) and the whole batch goes
    /// through one [`super::engine::Engine::run_batch`] dispatch.
    pub fn evaluate_batch(
        &self,
        net: &Network,
        candidates: &[Strategy],
    ) -> Result<Vec<DenseEval>> {
        use anyhow::Context as _;
        if candidates.is_empty() {
            return Ok(Vec::new());
        }
        let class = self
            .engine
            .class_for(net.n(), net.s())
            .with_context(|| {
                format!("no size class fits N={} S={}", net.n(), net.s())
            })?;
        let inputs: Vec<DenseInputs> = candidates
            .iter()
            .map(|phi| pack(net, phi, class.n, class.s))
            .collect::<Result<_>>()?;
        let outs = self.engine.run_batch(&inputs)?;
        Ok(outs.iter().map(|out| unpack(net, out)).collect())
    }
}

#[cfg(feature = "pjrt")]
impl super::backend::DenseBackend for DenseEvaluator<'_> {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn evaluate(&self, net: &Network, phi: &Strategy) -> Result<DenseEval> {
        DenseEvaluator::evaluate(self, net, phi)
    }

    fn evaluate_batch(&self, net: &Network, candidates: &[Strategy]) -> Result<Vec<DenseEval>> {
        DenseEvaluator::evaluate_batch(self, net, candidates)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::network::testnet::diamond;

    #[test]
    fn pack_shapes_and_padding_identity() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        let inp = pack(&net, &phi, 8, 4).unwrap();
        assert_eq!(inp.phi_data.len(), 4 * 8 * 8);
        // padded tasks: zero rates, local fraction 1
        for task in net.s()..4 {
            for i in 0..8 {
                assert_eq!(inp.r[task * 8 + i], 0.0);
                assert_eq!(inp.phi_local[task * 8 + i], 1.0);
            }
        }
        // padded nodes are masked out of the link plane
        for i in 0..8 {
            for j in net.n()..8 {
                assert_eq!(inp.link_mask[i * 8 + j], 0.0);
            }
        }
        // real edges present with queue kind
        let e01 = net.graph.edge_id(0, 1).unwrap();
        let _ = e01;
        assert_eq!(inp.link_mask[1], 1.0); // edge (0,1) at idx 0*8+1
        assert_eq!(inp.link_kind[1], 1.0);
        assert_eq!(inp.link_param[1], 10.0);
    }

    #[test]
    fn pack_rejects_too_small_padding() {
        let net = diamond(true);
        let phi = Strategy::local_compute_init(&net);
        assert!(pack(&net, &phi, 2, 1).is_err());
    }

    #[test]
    fn pack_unpack_roundtrip_of_phi() {
        let net = diamond(true);
        let phi = Strategy::compute_at_dest_init(&net);
        let inp = pack(&net, &phi, 8, 2).unwrap();
        // φ entries land at (task, i, j)
        for i in 0..net.n() {
            for (k, &eid) in net.graph.out_edge_ids(i).iter().enumerate() {
                let j = net.graph.edge(eid).dst;
                assert_eq!(
                    inp.phi_data[i * 8 + j],
                    phi.data[0][i][k + 1] as f32,
                    "({i},{j})"
                );
            }
        }
    }
}
