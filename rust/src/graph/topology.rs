//! Topology generators and embedded real-world topologies for the Table II
//! scenarios of the paper.
//!
//! All topologies are built as undirected link sets carried as directed
//! edge pairs (the paper's links are bidirectional physical channels with
//! per-direction flows). `|E|` in Table II counts undirected links.
//!
//! Real topologies: the paper takes Abilene, GEANT and LHC from the Rossi &
//! Rossini CCN dataset [23], which is not shipped here. Abilene is embedded
//! exactly (its 11-node / 14-link layout is public and unambiguous); GEANT
//! and LHC are embedded as faithful reconstructions with the exact node and
//! link counts from Table II (22/33 and 16/31). The experiments re-randomize
//! rates, capacities and task placements anyway (§V), so only the size and
//! connectivity structure matter — see DESIGN.md §3.6.

use super::digraph::{from_undirected, DiGraph};
use crate::util::rng::Pcg;

/// Named topology kinds used throughout configs, CLI and benches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopologyKind {
    ConnectedEr,
    BalancedTree,
    Fog,
    Abilene,
    Lhc,
    Geant,
    SmallWorld,
    /// 5×4 torus grid ([`grid_torus`]) — every node degree exactly 4.
    Torus,
    /// Barabási–Albert scale-free graph ([`barabasi_albert`], n=25, m=2).
    ScaleFree,
    /// k=4 fat-tree ([`fat_tree`]): 4 cores + 4 pods of 2 agg + 2 edge.
    FatTree,
}

impl TopologyKind {
    pub fn parse(name: &str) -> Option<TopologyKind> {
        Some(match name.to_ascii_lowercase().as_str() {
            "connected-er" | "er" | "connected_er" => TopologyKind::ConnectedEr,
            "balanced-tree" | "tree" | "balanced_tree" => TopologyKind::BalancedTree,
            "fog" => TopologyKind::Fog,
            "abilene" => TopologyKind::Abilene,
            "lhc" => TopologyKind::Lhc,
            "geant" => TopologyKind::Geant,
            "sw" | "small-world" | "small_world" => TopologyKind::SmallWorld,
            "grid-torus" | "torus" | "grid_torus" => TopologyKind::Torus,
            "scale-free" | "ba" | "scale_free" | "barabasi-albert" => TopologyKind::ScaleFree,
            "fat-tree" | "fattree" | "fat_tree" => TopologyKind::FatTree,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            TopologyKind::ConnectedEr => "connected-er",
            TopologyKind::BalancedTree => "balanced-tree",
            TopologyKind::Fog => "fog",
            TopologyKind::Abilene => "abilene",
            TopologyKind::Lhc => "lhc",
            TopologyKind::Geant => "geant",
            TopologyKind::SmallWorld => "sw",
            TopologyKind::Torus => "grid-torus",
            TopologyKind::ScaleFree => "scale-free",
            TopologyKind::FatTree => "fat-tree",
        }
    }

    pub fn all() -> &'static [TopologyKind] {
        &[
            TopologyKind::ConnectedEr,
            TopologyKind::BalancedTree,
            TopologyKind::Fog,
            TopologyKind::Abilene,
            TopologyKind::Lhc,
            TopologyKind::Geant,
            TopologyKind::SmallWorld,
            TopologyKind::Torus,
            TopologyKind::ScaleFree,
            TopologyKind::FatTree,
        ]
    }

    /// Build the topology at its Table II (or extended-library) size.
    pub fn build(&self, rng: &mut Pcg) -> DiGraph {
        match self {
            TopologyKind::ConnectedEr => connected_er(20, 40, rng),
            TopologyKind::BalancedTree => balanced_tree(15),
            TopologyKind::Fog => fog(&[1, 2, 4, 12]),
            TopologyKind::Abilene => abilene(),
            TopologyKind::Lhc => lhc(),
            TopologyKind::Geant => geant(),
            TopologyKind::SmallWorld => small_world(100, 320, rng),
            TopologyKind::Torus => grid_torus(5, 4, true),
            TopologyKind::ScaleFree => barabasi_albert(25, 2, rng),
            TopologyKind::FatTree => fat_tree(4),
        }
    }
}

/// Connectivity-guaranteed Erdős–Rényi graph (§V): a linear chain
/// concatenating all nodes guarantees connectivity, then random extra
/// links are added until exactly `links` undirected links exist.
///
/// The paper describes "creating links with probability p = 0.1" and
/// reports |E| = 40 for |V| = 20; we draw links until the reported count is
/// hit so every seed reproduces the Table II size exactly.
pub fn connected_er(n: usize, links: usize, rng: &mut Pcg) -> DiGraph {
    assert!(n >= 2);
    assert!(
        links >= n - 1,
        "need at least n-1={} links for connectivity",
        n - 1
    );
    assert!(links <= n * (n - 1) / 2, "too many links requested");
    let mut pairs: Vec<(usize, usize)> = (0..n - 1).map(|i| (i, i + 1)).collect();
    let mut have = vec![false; n * n];
    for &(u, v) in &pairs {
        have[u * n + v] = true;
        have[v * n + u] = true;
    }
    while pairs.len() < links {
        let u = rng.below(n);
        let v = rng.below(n);
        if u != v && !have[u * n + v] {
            have[u * n + v] = true;
            have[v * n + u] = true;
            pairs.push((u.min(v), u.max(v)));
        }
    }
    from_undirected(n, &pairs)
}

/// Complete balanced binary tree with `n` nodes (node 0 is the root,
/// children of `i` are `2i+1`, `2i+2`). Table II: n = 15 (depth 4).
pub fn balanced_tree(n: usize) -> DiGraph {
    let mut pairs = Vec::new();
    for i in 0..n {
        for c in [2 * i + 1, 2 * i + 2] {
            if c < n {
                pairs.push((i, c));
            }
        }
    }
    from_undirected(n, &pairs)
}

/// Fog-computing topology (paper ref [22]): a balanced tree whose layers
/// are given by `layer_sizes` (root first), with nodes on the same layer
/// additionally linked in a line. Children are distributed evenly over the
/// parents of the previous layer.
pub fn fog(layer_sizes: &[usize]) -> DiGraph {
    assert!(!layer_sizes.is_empty() && layer_sizes[0] >= 1);
    let n: usize = layer_sizes.iter().sum();
    let mut pairs = Vec::new();
    // assign node ids layer by layer
    let mut layer_start = Vec::with_capacity(layer_sizes.len());
    let mut acc = 0;
    for &sz in layer_sizes {
        layer_start.push(acc);
        acc += sz;
    }
    for l in 1..layer_sizes.len() {
        let (pstart, psz) = (layer_start[l - 1], layer_sizes[l - 1]);
        let (cstart, csz) = (layer_start[l], layer_sizes[l]);
        for c in 0..csz {
            // even distribution of children over parents
            let p = pstart + (c * psz) / csz;
            pairs.push((p, cstart + c));
        }
        // intra-layer line links
        for c in 1..csz {
            pairs.push((cstart + c - 1, cstart + c));
        }
    }
    from_undirected(n, &pairs)
}

/// Abilene — the Internet2 predecessor backbone, 11 PoPs / 14 links.
/// Node order: 0 Seattle, 1 Sunnyvale, 2 Los Angeles, 3 Denver,
/// 4 Kansas City, 5 Houston, 6 Chicago, 7 Indianapolis, 8 Atlanta,
/// 9 Washington DC, 10 New York.
pub fn abilene() -> DiGraph {
    let links = [
        (0, 1),  // Seattle - Sunnyvale
        (0, 3),  // Seattle - Denver
        (1, 2),  // Sunnyvale - Los Angeles
        (1, 3),  // Sunnyvale - Denver
        (2, 5),  // Los Angeles - Houston
        (3, 4),  // Denver - Kansas City
        (4, 5),  // Kansas City - Houston
        (4, 7),  // Kansas City - Indianapolis
        (5, 8),  // Houston - Atlanta
        (6, 7),  // Chicago - Indianapolis
        (6, 10), // Chicago - New York
        (7, 8),  // Indianapolis - Atlanta
        (8, 9),  // Atlanta - Washington DC
        (9, 10), // Washington DC - New York
    ];
    from_undirected(11, &links)
}

/// LHC computing-grid topology, 16 nodes / 31 links — reconstruction of the
/// dataset used by [23]: a CERN hub (node 0) meshed with Tier-1 centres
/// (1..=6) which fan out to Tier-2 sites (7..=15).
pub fn lhc() -> DiGraph {
    let links = [
        // CERN Tier-0 to Tier-1 ring
        (0, 1),
        (0, 2),
        (0, 3),
        (0, 4),
        (0, 5),
        (0, 6),
        // Tier-1 lateral mesh
        (1, 2),
        (2, 3),
        (3, 4),
        (4, 5),
        (5, 6),
        (6, 1),
        (1, 4),
        // Tier-2 attachments (dual-homed)
        (7, 1),
        (7, 2),
        (8, 2),
        (8, 3),
        (9, 3),
        (9, 4),
        (10, 4),
        (10, 5),
        (11, 5),
        (11, 6),
        (12, 6),
        (12, 1),
        (13, 2),
        (13, 5),
        (14, 3),
        (14, 6),
        (15, 7),
        (15, 8),
    ];
    from_undirected(16, &links)
}

/// GEANT pan-European research network, 22 nodes / 33 links —
/// reconstruction of the GEANT backbone as used by [23].
/// Node key (approximate): 0 UK, 1 FR, 2 BE, 3 NL, 4 DE, 5 CH, 6 IT,
/// 7 ES, 8 PT, 9 IE, 10 AT, 11 CZ, 12 PL, 13 HU, 14 SK, 15 SI, 16 HR,
/// 17 GR, 18 SE, 19 DK, 20 NO, 21 FI.
pub fn geant() -> DiGraph {
    let links = [
        (0, 1),  // UK-FR
        (0, 2),  // UK-BE
        (0, 3),  // UK-NL
        (0, 9),  // UK-IE
        (1, 5),  // FR-CH
        (1, 7),  // FR-ES
        (1, 2),  // FR-BE
        (2, 3),  // BE-NL
        (3, 4),  // NL-DE
        (3, 19), // NL-DK
        (4, 5),  // DE-CH
        (4, 10), // DE-AT
        (4, 11), // DE-CZ
        (4, 12), // DE-PL
        (4, 19), // DE-DK
        (5, 6),  // CH-IT
        (6, 10), // IT-AT
        (6, 17), // IT-GR
        (7, 8),  // ES-PT
        (7, 6),  // ES-IT
        (8, 0),  // PT-UK (Atlantic path)
        (9, 3),  // IE-NL
        (10, 13), // AT-HU
        (10, 15), // AT-SI
        (11, 14), // CZ-SK
        (12, 11), // PL-CZ
        (13, 14), // HU-SK
        (13, 16), // HU-HR
        (15, 16), // SI-HR
        (17, 13), // GR-HU
        (18, 19), // SE-DK
        (18, 20), // SE-NO
        (18, 21), // SE-FI
    ];
    from_undirected(22, &links)
}

/// Small-world graph (Kleinberg [24], §V "SW"): a ring with distance-2
/// chords (short range) plus random long-range links added until exactly
/// `links` undirected links exist. Table II: 100 nodes, 320 links.
pub fn small_world(n: usize, links: usize, rng: &mut Pcg) -> DiGraph {
    assert!(n >= 5);
    let mut pairs: Vec<(usize, usize)> = Vec::new();
    let mut have = vec![false; n * n];
    let push = |pairs: &mut Vec<(usize, usize)>, have: &mut Vec<bool>, u: usize, v: usize| {
        if u != v && !have[u * n + v] {
            have[u * n + v] = true;
            have[v * n + u] = true;
            pairs.push((u, v));
            true
        } else {
            false
        }
    };
    // ring
    for i in 0..n {
        push(&mut pairs, &mut have, i, (i + 1) % n);
    }
    // short-range chords (distance 2)
    for i in 0..n {
        if pairs.len() >= links {
            break;
        }
        push(&mut pairs, &mut have, i, (i + 2) % n);
    }
    // long-range random chords
    while pairs.len() < links {
        let u = rng.below(n);
        let v = rng.below(n);
        // Kleinberg-flavored: prefer moderately distant targets
        let dist = {
            let d = if u > v { u - v } else { v - u };
            d.min(n - d)
        };
        if dist >= 3 {
            push(&mut pairs, &mut have, u, v);
        }
    }
    from_undirected(n, &pairs)
}

/// Rectangular grid of `rows × cols` nodes (node `(r, c)` is `r·cols +
/// c`), linked to the right/down neighbors; with `wrap` the rows and
/// columns close into rings (a torus — every node degree exactly 4 when
/// both dimensions are ≥ 3). Deterministic: no randomness enters the
/// construction.
pub fn grid_torus(rows: usize, cols: usize, wrap: bool) -> DiGraph {
    assert!(rows >= 2 && cols >= 2, "grid needs at least 2×2 nodes");
    if wrap {
        assert!(
            rows >= 3 && cols >= 3,
            "torus wrap needs both dimensions ≥ 3 (2-rings would duplicate links)"
        );
    }
    let id = |r: usize, c: usize| r * cols + c;
    let mut pairs = Vec::new();
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                pairs.push((id(r, c), id(r, c + 1)));
            } else if wrap {
                pairs.push((id(r, c), id(r, 0)));
            }
            if r + 1 < rows {
                pairs.push((id(r, c), id(r + 1, c)));
            } else if wrap {
                pairs.push((id(r, c), id(0, c)));
            }
        }
    }
    from_undirected(rows * cols, &pairs)
}

/// Barabási–Albert scale-free graph: a complete seed graph on `m + 1`
/// nodes, then each new node attaches to `m` distinct existing nodes
/// chosen by preferential attachment (probability proportional to
/// degree). Undirected link count is `m(m+1)/2 + (n − m − 1)·m`; connected
/// by construction, and bitwise reproducible from the generator state.
pub fn barabasi_albert(n: usize, m: usize, rng: &mut Pcg) -> DiGraph {
    assert!(m >= 1, "BA needs m ≥ 1");
    let m0 = m + 1;
    assert!(n > m0, "BA needs n > m + 1 (got n={n}, m={m})");
    let mut pairs: Vec<(usize, usize)> = Vec::new();
    // one entry per incident link end: sampling it uniformly is sampling
    // nodes proportionally to degree
    let mut stubs: Vec<usize> = Vec::new();
    for u in 0..m0 {
        for v in (u + 1)..m0 {
            pairs.push((u, v));
            stubs.push(u);
            stubs.push(v);
        }
    }
    for v in m0..n {
        let mut chosen: Vec<usize> = Vec::with_capacity(m);
        while chosen.len() < m {
            let t = *rng.pick(&stubs);
            if !chosen.contains(&t) {
                chosen.push(t);
            }
        }
        for &t in &chosen {
            pairs.push((t, v));
            stubs.push(t);
            stubs.push(v);
        }
    }
    from_undirected(n, &pairs)
}

/// k-ary fat-tree switching fabric (`k` even): `(k/2)²` core nodes and
/// `k` pods of `k/2` aggregation + `k/2` edge nodes. Edge node `e` of a
/// pod links to every aggregation node of its pod; aggregation node `a`
/// links to the `k/2` cores of core group `a`. Node ids: cores first,
/// then pod by pod (aggregation before edge). Max degree is exactly `k`
/// (cores and aggregation), edge nodes have degree `k/2`.
pub fn fat_tree(k: usize) -> DiGraph {
    assert!(k >= 2 && k % 2 == 0, "fat-tree needs an even k ≥ 2");
    let h = k / 2;
    let cores = h * h;
    let n = cores + k * k;
    let mut pairs = Vec::new();
    for p in 0..k {
        let agg0 = cores + p * k;
        let edge0 = agg0 + h;
        for a in 0..h {
            for e in 0..h {
                pairs.push((agg0 + a, edge0 + e));
            }
            for c in 0..h {
                pairs.push((a * h + c, agg0 + a));
            }
        }
    }
    from_undirected(n, &pairs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::algorithms::strongly_connected;

    #[test]
    fn abilene_matches_table2() {
        let g = abilene();
        assert_eq!(g.node_count(), 11);
        assert_eq!(g.edge_count(), 28); // 14 undirected links
        assert!(strongly_connected(&g));
    }

    #[test]
    fn geant_matches_table2() {
        let g = geant();
        assert_eq!(g.node_count(), 22);
        assert_eq!(g.edge_count(), 66); // 33 links
        assert!(strongly_connected(&g));
    }

    #[test]
    fn lhc_matches_table2() {
        let g = lhc();
        assert_eq!(g.node_count(), 16);
        assert_eq!(g.edge_count(), 62); // 31 links... see below
        assert!(strongly_connected(&g));
    }

    #[test]
    fn balanced_tree_matches_table2() {
        let g = balanced_tree(15);
        assert_eq!(g.node_count(), 15);
        assert_eq!(g.edge_count(), 28); // 14 links
        assert!(strongly_connected(&g));
    }

    #[test]
    fn connected_er_matches_table2() {
        let mut rng = Pcg::new(1);
        let g = connected_er(20, 40, &mut rng);
        assert_eq!(g.node_count(), 20);
        assert_eq!(g.edge_count(), 80); // 40 links
        assert!(strongly_connected(&g));
    }

    #[test]
    fn connected_er_deterministic_per_seed() {
        let a = connected_er(20, 40, &mut Pcg::new(7));
        let b = connected_er(20, 40, &mut Pcg::new(7));
        assert_eq!(a.edges(), b.edges());
    }

    #[test]
    fn small_world_matches_table2() {
        let mut rng = Pcg::new(2);
        let g = small_world(100, 320, &mut rng);
        assert_eq!(g.node_count(), 100);
        assert_eq!(g.edge_count(), 640); // 320 links
        assert!(strongly_connected(&g));
    }

    #[test]
    fn fog_structure() {
        let g = fog(&[1, 2, 4, 12]);
        assert_eq!(g.node_count(), 19); // Table II |V| = 19
        assert!(strongly_connected(&g));
        // root links only to layer 1
        let root_deg = g.out_degree(0);
        assert_eq!(root_deg, 2);
    }

    #[test]
    fn kind_parse_roundtrip() {
        for k in TopologyKind::all() {
            assert_eq!(TopologyKind::parse(k.name()), Some(*k));
        }
        assert_eq!(TopologyKind::parse("nope"), None);
    }

    #[test]
    fn build_all_kinds_strongly_connected() {
        for k in TopologyKind::all() {
            let mut rng = Pcg::new(11);
            let g = k.build(&mut rng);
            assert!(
                strongly_connected(&g),
                "{} not strongly connected",
                k.name()
            );
        }
    }
}
