//! Graph substrate: the directed network `G = (V, E)` of §II, generators
//! for every Table II topology, and the graph algorithms the optimizer and
//! baselines rely on.

pub mod algorithms;
pub mod digraph;
pub mod topology;

pub use digraph::{from_undirected, DiGraph, Edge};
pub use topology::TopologyKind;
