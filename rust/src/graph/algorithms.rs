//! Graph algorithms needed by the flow model and the baselines:
//! Dijkstra shortest paths (SPOO / LPR), strong-connectivity (scenario
//! validation, §II requires strongly connected G), topological sorting of
//! the φ-induced active subgraphs (exact flow/marginal computation), and
//! cycle detection (loop-freedom invariant checks).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::digraph::DiGraph;

/// Result of a single-source Dijkstra run.
#[derive(Clone, Debug)]
pub struct ShortestPaths {
    pub dist: Vec<f64>,
    /// Predecessor node on a shortest path, usize::MAX for source/unreached.
    pub prev: Vec<usize>,
}

#[derive(PartialEq)]
struct HeapItem {
    dist: f64,
    node: usize,
}

impl Eq for HeapItem {}

impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap on dist
        other
            .dist
            .partial_cmp(&self.dist)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.node.cmp(&self.node))
    }
}

/// Dijkstra from `src` with per-edge weights `w[edge_id]` (must be >= 0).
pub fn dijkstra(g: &DiGraph, src: usize, w: &[f64]) -> ShortestPaths {
    assert_eq!(w.len(), g.edge_count());
    debug_assert!(w.iter().all(|&x| x >= 0.0), "negative edge weight");
    let n = g.node_count();
    let mut dist = vec![f64::INFINITY; n];
    let mut prev = vec![usize::MAX; n];
    let mut heap = BinaryHeap::new();
    dist[src] = 0.0;
    heap.push(HeapItem { dist: 0.0, node: src });
    while let Some(HeapItem { dist: d, node: u }) = heap.pop() {
        if d > dist[u] {
            continue;
        }
        for &eid in g.out_edge_ids(u) {
            let v = g.edge(eid).dst;
            let nd = d + w[eid];
            if nd < dist[v] {
                dist[v] = nd;
                prev[v] = u;
                heap.push(HeapItem { dist: nd, node: v });
            }
        }
    }
    ShortestPaths { dist, prev }
}

/// Dijkstra on the *reverse* graph: `dist[i]` = cost of the cheapest path
/// from `i` **to** `dst`. `next[i]` is the next hop along that path.
/// This is the form the SPOO / LPR baselines need (route-toward-destination
/// trees).
pub fn dijkstra_to(g: &DiGraph, dst: usize, w: &[f64]) -> (Vec<f64>, Vec<usize>) {
    assert_eq!(w.len(), g.edge_count());
    let n = g.node_count();
    let mut dist = vec![f64::INFINITY; n];
    let mut next = vec![usize::MAX; n];
    let mut heap = BinaryHeap::new();
    dist[dst] = 0.0;
    heap.push(HeapItem { dist: 0.0, node: dst });
    while let Some(HeapItem { dist: d, node: u }) = heap.pop() {
        if d > dist[u] {
            continue;
        }
        // traverse incoming edges: predecessor v reaches dst through u
        for &eid in g.in_edge_ids(u) {
            let v = g.edge(eid).src;
            let nd = d + w[eid];
            if nd < dist[v] {
                dist[v] = nd;
                next[v] = u;
                heap.push(HeapItem { dist: nd, node: v });
            }
        }
    }
    (dist, next)
}

/// Extract the path `src -> ... -> dst` from a `dijkstra_to` next-hop map.
pub fn path_from_next(next: &[usize], src: usize, dst: usize) -> Option<Vec<usize>> {
    let mut path = vec![src];
    let mut cur = src;
    while cur != dst {
        let nxt = next[cur];
        if nxt == usize::MAX || path.len() > next.len() {
            return None;
        }
        path.push(nxt);
        cur = nxt;
    }
    Some(path)
}

/// Is the directed graph strongly connected? (BFS out + BFS on reverse.)
pub fn strongly_connected(g: &DiGraph) -> bool {
    let n = g.node_count();
    if n == 0 {
        return true;
    }
    let reach = |forward: bool| -> usize {
        let mut seen = vec![false; n];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = stack.pop() {
            let nexts: Vec<usize> = if forward {
                g.out_neighbors(u).collect()
            } else {
                g.in_neighbors(u).collect()
            };
            for v in nexts {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    stack.push(v);
                }
            }
        }
        count
    };
    reach(true) == n && reach(false) == n
}

/// Reusable scratch for the masked-topological-sort family so hot callers
/// (flat marginal recomputation, the SGP loop-freedom re-checks) run
/// allocation-free after warm-up.
#[derive(Clone, Debug, Default)]
pub struct TopoScratch {
    indeg: Vec<usize>,
    queue: Vec<usize>,
}

/// Allocation-free form of [`topo_order_masked`]: writes the order into
/// `order` and returns `true` iff the active subgraph is acyclic. The
/// traversal (Kahn with a LIFO stack seeded `0..n`) is identical to the
/// allocating form, so downstream FP reductions see the same node order.
pub fn topo_order_masked_into(
    g: &DiGraph,
    active: &[bool],
    scratch: &mut TopoScratch,
    order: &mut Vec<usize>,
) -> bool {
    assert_eq!(active.len(), g.edge_count());
    let n = g.node_count();
    let indeg = &mut scratch.indeg;
    indeg.clear();
    indeg.resize(n, 0);
    for (eid, &on) in active.iter().enumerate() {
        if on {
            indeg[g.edge(eid).dst] += 1;
        }
    }
    let queue = &mut scratch.queue;
    queue.clear();
    queue.extend((0..n).filter(|&i| indeg[i] == 0));
    order.clear();
    order.reserve(n);
    while let Some(u) = queue.pop() {
        order.push(u);
        for &eid in g.out_edge_ids(u) {
            if active[eid] {
                let v = g.edge(eid).dst;
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    queue.push(v);
                }
            }
        }
    }
    order.len() == n
}

/// Kahn topological order over a subgraph given by an edge mask
/// (`active[edge_id]`). Nodes not touching active edges still appear.
/// Returns `None` if the active subgraph has a cycle.
pub fn topo_order_masked(g: &DiGraph, active: &[bool]) -> Option<Vec<usize>> {
    let mut scratch = TopoScratch::default();
    let mut order = Vec::new();
    if topo_order_masked_into(g, active, &mut scratch, &mut order) {
        Some(order)
    } else {
        None // cycle among the remaining nodes
    }
}

/// Does the active subgraph contain a directed cycle?
pub fn has_cycle_masked(g: &DiGraph, active: &[bool]) -> bool {
    topo_order_masked(g, active).is_none()
}

/// Allocation-free cycle check reusing caller-owned scratch.
pub fn has_cycle_masked_into(
    g: &DiGraph,
    active: &[bool],
    scratch: &mut TopoScratch,
    order: &mut Vec<usize>,
) -> bool {
    !topo_order_masked_into(g, active, scratch, order)
}

/// Allocation-free companion to [`longest_path_to_sink`] for callers that
/// already hold a topological order of the *same* active mask: fills
/// `h[i]` = max hops from `i` to a sink along active edges. `h.len()` must
/// equal the node count.
pub fn longest_path_to_sink_into(
    g: &DiGraph,
    active: &[bool],
    order: &[usize],
    h: &mut [usize],
) {
    debug_assert_eq!(h.len(), g.node_count());
    for x in h.iter_mut() {
        *x = 0;
    }
    // process in reverse topological order so successors are final
    for &u in order.iter().rev() {
        for &eid in g.out_edge_ids(u) {
            if active[eid] {
                let v = g.edge(eid).dst;
                h[u] = h[u].max(1 + h[v]);
            }
        }
    }
}

/// Longest path length (hop count) ending analysis over a DAG given by the
/// edge mask: `h[i]` = max hops from `i` along active edges to any sink.
/// Returns `None` on cycles. This is the paper's `h±` statistic feeding the
/// scaling matrices (16).
pub fn longest_path_to_sink(g: &DiGraph, active: &[bool]) -> Option<Vec<usize>> {
    let order = topo_order_masked(g, active)?;
    let mut h = vec![0usize; g.node_count()];
    longest_path_to_sink_into(g, active, &order, &mut h);
    Some(h)
}

/// Floyd–Warshall all-pairs shortest paths — O(n³), used only by tests as
/// a brute-force oracle for Dijkstra.
pub fn floyd_warshall(g: &DiGraph, w: &[f64]) -> Vec<Vec<f64>> {
    let n = g.node_count();
    let mut d = vec![vec![f64::INFINITY; n]; n];
    for i in 0..n {
        d[i][i] = 0.0;
    }
    for (eid, e) in g.edges().iter().enumerate() {
        if w[eid] < d[e.src][e.dst] {
            d[e.src][e.dst] = w[eid];
        }
    }
    for k in 0..n {
        for i in 0..n {
            if d[i][k].is_infinite() {
                continue;
            }
            for j in 0..n {
                let via = d[i][k] + d[k][j];
                if via < d[i][j] {
                    d[i][j] = via;
                }
            }
        }
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn grid3() -> (DiGraph, Vec<f64>) {
        // 0-1-2 / 3-4-5 grid, bidirectional, unit-ish weights
        let links = [
            (0, 1),
            (1, 2),
            (3, 4),
            (4, 5),
            (0, 3),
            (1, 4),
            (2, 5),
        ];
        let g = super::super::digraph::from_undirected(6, &links);
        let w = vec![1.0; g.edge_count()];
        (g, w)
    }

    #[test]
    fn dijkstra_simple_distances() {
        let (g, w) = grid3();
        let sp = dijkstra(&g, 0, &w);
        assert_eq!(sp.dist[0], 0.0);
        assert_eq!(sp.dist[1], 1.0);
        assert_eq!(sp.dist[5], 3.0);
    }

    #[test]
    fn dijkstra_respects_weights() {
        let g = DiGraph::new(3, &[(0, 1), (1, 2), (0, 2)]);
        let w = vec![1.0, 1.0, 5.0];
        let sp = dijkstra(&g, 0, &w);
        assert_eq!(sp.dist[2], 2.0); // via node 1, not direct
        assert_eq!(sp.prev[2], 1);
    }

    #[test]
    fn dijkstra_matches_floyd_warshall_random() {
        let mut rng = Pcg::new(99);
        for trial in 0..20 {
            let n = rng.int_range(4, 12);
            let mut edges = Vec::new();
            for u in 0..n {
                for v in 0..n {
                    if u != v && rng.chance(0.4) {
                        edges.push((u, v));
                    }
                }
            }
            if edges.is_empty() {
                continue;
            }
            let g = DiGraph::new(n, &edges);
            let w: Vec<f64> = (0..g.edge_count()).map(|_| rng.uniform(0.1, 3.0)).collect();
            let fw = floyd_warshall(&g, &w);
            for src in 0..n {
                let sp = dijkstra(&g, src, &w);
                for v in 0..n {
                    let a = sp.dist[v];
                    let b = fw[src][v];
                    assert!(
                        (a - b).abs() < 1e-9 || (a.is_infinite() && b.is_infinite()),
                        "trial {trial}: dist({src},{v}) dijkstra={a} fw={b}"
                    );
                }
            }
        }
    }

    #[test]
    fn dijkstra_to_gives_next_hops() {
        let (g, w) = grid3();
        let (dist, next) = dijkstra_to(&g, 5, &w);
        assert_eq!(dist[5], 0.0);
        assert_eq!(dist[0], 3.0);
        let path = path_from_next(&next, 0, 5).unwrap();
        assert_eq!(path.len(), 4);
        assert_eq!(*path.first().unwrap(), 0);
        assert_eq!(*path.last().unwrap(), 5);
        // consecutive hops are edges
        for win in path.windows(2) {
            assert!(g.has_edge(win[0], win[1]));
        }
    }

    #[test]
    fn strong_connectivity() {
        let cyc = DiGraph::new(3, &[(0, 1), (1, 2), (2, 0)]);
        assert!(strongly_connected(&cyc));
        let dag = DiGraph::new(3, &[(0, 1), (1, 2)]);
        assert!(!strongly_connected(&dag));
        let (g, _) = grid3();
        assert!(strongly_connected(&g));
    }

    #[test]
    fn topo_order_on_dag() {
        let g = DiGraph::new(4, &[(0, 1), (0, 2), (1, 3), (2, 3)]);
        let active = vec![true; 4];
        let order = topo_order_masked(&g, &active).unwrap();
        let pos: Vec<usize> = {
            let mut p = vec![0; 4];
            for (i, &u) in order.iter().enumerate() {
                p[u] = i;
            }
            p
        };
        assert!(pos[0] < pos[1] && pos[0] < pos[2]);
        assert!(pos[1] < pos[3] && pos[2] < pos[3]);
    }

    #[test]
    fn topo_order_detects_cycle() {
        let g = DiGraph::new(3, &[(0, 1), (1, 2), (2, 0)]);
        assert!(topo_order_masked(&g, &[true, true, true]).is_none());
        assert!(has_cycle_masked(&g, &[true, true, true]));
        // masking one edge breaks the cycle
        assert!(!has_cycle_masked(&g, &[true, true, false]));
    }

    #[test]
    fn masked_edges_ignored() {
        let g = DiGraph::new(3, &[(0, 1), (1, 2), (2, 0)]);
        let order = topo_order_masked(&g, &[true, false, false]).unwrap();
        assert_eq!(order.len(), 3);
    }

    #[test]
    fn longest_path_dag() {
        // chain 0->1->2->3 plus shortcut 0->3
        let g = DiGraph::new(4, &[(0, 1), (1, 2), (2, 3), (0, 3)]);
        let h = longest_path_to_sink(&g, &[true, true, true, true]).unwrap();
        assert_eq!(h, vec![3, 2, 1, 0]);
    }

    #[test]
    fn longest_path_none_on_cycle() {
        let g = DiGraph::new(2, &[(0, 1), (1, 0)]);
        assert!(longest_path_to_sink(&g, &[true, true]).is_none());
    }
}
