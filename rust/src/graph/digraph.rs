//! Directed graph with CSR-style adjacency.
//!
//! The paper's network `G = (V, E)` is a directed, strongly connected graph
//! (§II). Nodes are dense indices `0..n`; every directed edge gets a stable
//! edge id used to index flow vectors `F_ij` and cost parameters.

/// Directed edge endpoint pair.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Edge {
    pub src: usize,
    pub dst: usize,
}

/// Directed graph over dense node ids with O(1) out/in neighbor slices.
#[derive(Clone, Debug)]
pub struct DiGraph {
    n: usize,
    edges: Vec<Edge>,
    /// CSR over outgoing edges: `out_off[i]..out_off[i+1]` indexes `out_edges`.
    out_off: Vec<usize>,
    out_edges: Vec<usize>, // edge ids sorted by src
    in_off: Vec<usize>,
    in_edges: Vec<usize>, // edge ids sorted by dst
    /// edge id lookup by (src,dst); dense matrix for the graph sizes we use.
    eid: Vec<u32>,
}

pub const NO_EDGE: u32 = u32::MAX;

impl DiGraph {
    /// Build from an edge list. Parallel edges are rejected; self-loops are
    /// rejected (the flow model has no use for them and loop-freedom
    /// bookkeeping assumes their absence).
    pub fn new(n: usize, edge_list: &[(usize, usize)]) -> DiGraph {
        let mut eid = vec![NO_EDGE; n * n];
        let mut edges = Vec::with_capacity(edge_list.len());
        for &(u, v) in edge_list {
            assert!(u < n && v < n, "edge ({u},{v}) out of range for n={n}");
            assert_ne!(u, v, "self-loop ({u},{v}) not allowed");
            assert_eq!(
                eid[u * n + v], NO_EDGE,
                "duplicate edge ({u},{v})"
            );
            eid[u * n + v] = edges.len() as u32;
            edges.push(Edge { src: u, dst: v });
        }

        let mut out_off = vec![0usize; n + 1];
        let mut in_off = vec![0usize; n + 1];
        for e in &edges {
            out_off[e.src + 1] += 1;
            in_off[e.dst + 1] += 1;
        }
        for i in 0..n {
            out_off[i + 1] += out_off[i];
            in_off[i + 1] += in_off[i];
        }
        let mut out_edges = vec![0usize; edges.len()];
        let mut in_edges = vec![0usize; edges.len()];
        let mut out_cursor = out_off.clone();
        let mut in_cursor = in_off.clone();
        for (id, e) in edges.iter().enumerate() {
            out_edges[out_cursor[e.src]] = id;
            out_cursor[e.src] += 1;
            in_edges[in_cursor[e.dst]] = id;
            in_cursor[e.dst] += 1;
        }

        DiGraph {
            n,
            edges,
            out_off,
            out_edges,
            in_off,
            in_edges,
            eid,
        }
    }

    pub fn node_count(&self) -> usize {
        self.n
    }

    pub fn edge_count(&self) -> usize {
        self.edges.len()
    }

    pub fn edge(&self, id: usize) -> Edge {
        self.edges[id]
    }

    pub fn edges(&self) -> &[Edge] {
        &self.edges
    }

    /// Edge id of (u,v) if present.
    pub fn edge_id(&self, u: usize, v: usize) -> Option<usize> {
        let id = self.eid[u * self.n + v];
        if id == NO_EDGE {
            None
        } else {
            Some(id as usize)
        }
    }

    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        self.eid[u * self.n + v] != NO_EDGE
    }

    /// Outgoing edge ids of node `i` — the paper's `O(i)` in edge form.
    pub fn out_edge_ids(&self, i: usize) -> &[usize] {
        &self.out_edges[self.out_off[i]..self.out_off[i + 1]]
    }

    /// Incoming edge ids of node `i` — the paper's `I(i)` in edge form.
    pub fn in_edge_ids(&self, i: usize) -> &[usize] {
        &self.in_edges[self.in_off[i]..self.in_off[i + 1]]
    }

    /// Out-neighbors `O(i)` as node ids.
    pub fn out_neighbors(&self, i: usize) -> impl Iterator<Item = usize> + '_ {
        self.out_edge_ids(i).iter().map(move |&e| self.edges[e].dst)
    }

    /// In-neighbors `I(i)` as node ids.
    pub fn in_neighbors(&self, i: usize) -> impl Iterator<Item = usize> + '_ {
        self.in_edge_ids(i).iter().map(move |&e| self.edges[e].src)
    }

    pub fn out_degree(&self, i: usize) -> usize {
        self.out_off[i + 1] - self.out_off[i]
    }

    pub fn in_degree(&self, i: usize) -> usize {
        self.in_off[i + 1] - self.in_off[i]
    }

    /// Maximum out-degree over nodes — `d̄` in the paper's complexity model.
    pub fn max_out_degree(&self) -> usize {
        (0..self.n).map(|i| self.out_degree(i)).max().unwrap_or(0)
    }

    /// Build a new graph with node `dead` isolated (all incident edges
    /// removed) — used for the Fig. 5b server-failure experiment. Node ids
    /// are preserved.
    pub fn without_node(&self, dead: usize) -> DiGraph {
        let kept: Vec<(usize, usize)> = self
            .edges
            .iter()
            .filter(|e| e.src != dead && e.dst != dead)
            .map(|e| (e.src, e.dst))
            .collect();
        DiGraph::new(self.n, &kept)
    }

    /// Symmetrize: ensure that for every (u,v) the reverse (v,u) exists.
    /// The paper's topologies are undirected physical links carried as a
    /// pair of directed edges.
    pub fn symmetrized(&self) -> DiGraph {
        let mut set: Vec<(usize, usize)> = self.edges.iter().map(|e| (e.src, e.dst)).collect();
        for e in &self.edges {
            if !self.has_edge(e.dst, e.src) {
                set.push((e.dst, e.src));
            }
        }
        DiGraph::new(self.n, &set)
    }
}

/// Convenience: build a directed graph from undirected link pairs,
/// inserting both directions.
pub fn from_undirected(n: usize, links: &[(usize, usize)]) -> DiGraph {
    let mut edges = Vec::with_capacity(links.len() * 2);
    for &(u, v) in links {
        assert_ne!(u, v, "self-link ({u},{v})");
        if !edges.contains(&(u, v)) {
            edges.push((u, v));
        }
        if !edges.contains(&(v, u)) {
            edges.push((v, u));
        }
    }
    DiGraph::new(n, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> DiGraph {
        // 0 -> 1 -> 3, 0 -> 2 -> 3
        DiGraph::new(4, &[(0, 1), (0, 2), (1, 3), (2, 3)])
    }

    #[test]
    fn counts_and_lookup() {
        let g = diamond();
        assert_eq!(g.node_count(), 4);
        assert_eq!(g.edge_count(), 4);
        assert!(g.has_edge(0, 1));
        assert!(!g.has_edge(1, 0));
        assert_eq!(g.edge_id(2, 3), Some(3));
        assert_eq!(g.edge_id(3, 2), None);
    }

    #[test]
    fn neighbor_views() {
        let g = diamond();
        let outs: Vec<usize> = g.out_neighbors(0).collect();
        assert_eq!(outs, vec![1, 2]);
        let ins: Vec<usize> = g.in_neighbors(3).collect();
        assert_eq!(ins, vec![1, 2]);
        assert_eq!(g.out_degree(0), 2);
        assert_eq!(g.in_degree(0), 0);
        assert_eq!(g.max_out_degree(), 2);
    }

    #[test]
    fn edge_ids_consistent_with_edges() {
        let g = diamond();
        for id in 0..g.edge_count() {
            let e = g.edge(id);
            assert_eq!(g.edge_id(e.src, e.dst), Some(id));
        }
        for i in 0..g.node_count() {
            for &eid in g.out_edge_ids(i) {
                assert_eq!(g.edge(eid).src, i);
            }
            for &eid in g.in_edge_ids(i) {
                assert_eq!(g.edge(eid).dst, i);
            }
        }
    }

    #[test]
    #[should_panic]
    fn rejects_duplicate_edges() {
        DiGraph::new(2, &[(0, 1), (0, 1)]);
    }

    #[test]
    #[should_panic]
    fn rejects_self_loop() {
        DiGraph::new(2, &[(1, 1)]);
    }

    #[test]
    fn without_node_isolates() {
        let g = diamond().without_node(1);
        assert!(!g.has_edge(0, 1));
        assert!(!g.has_edge(1, 3));
        assert!(g.has_edge(0, 2));
        assert_eq!(g.edge_count(), 2);
        assert_eq!(g.node_count(), 4); // ids preserved
    }

    #[test]
    fn undirected_builder_inserts_both_directions() {
        let g = from_undirected(3, &[(0, 1), (1, 2)]);
        assert_eq!(g.edge_count(), 4);
        assert!(g.has_edge(0, 1) && g.has_edge(1, 0));
        assert!(g.has_edge(1, 2) && g.has_edge(2, 1));
    }

    #[test]
    fn symmetrized_adds_missing_reverse() {
        let g = DiGraph::new(3, &[(0, 1), (1, 2), (2, 0)]).symmetrized();
        assert_eq!(g.edge_count(), 6);
        for e in g.edges() {
            assert!(g.has_edge(e.dst, e.src));
        }
    }
}
